//! Event-driven timing engine over the schedule IR ([`Plan`]).
//!
//! Each worker owns two streams — compute and comm — mirroring the
//! kernel/copy CUDA streams of the real system. Ops are scheduled by a
//! single deterministic pass in dependency order: an op starts at the max
//! of its release time, its honored dependencies' finishes, and its
//! stream's tail; streams are FIFO in plan order. That fixed-priority
//! discipline makes the simulation reproducible and *monotone in the
//! prefetch depth* (releasing a transfer earlier can only move every
//! start earlier), which is what the cross-engine tests pin.
//!
//! Transfer timing uses the per-link `(bandwidth, latency)` from
//! [`ClusterSpec::link`], so NVLink-vs-InfiniBand placement of every edge
//! matters — unlike the closed-form collectives, topology is emergent.
//!
//! ## Lock-step plans (schedule lowerings)
//!
//! Plans lowered from a [`Schedule`] carry `lockstep = true`: a barrier
//! separates consecutive `step` groups (the BSP semantics of the threaded
//! executor). [`EventOpts::prefetch_depth`] then controls communication:
//!
//! * `depth = 0` — no overlap: transfers are released at the *previous*
//!   step's barrier (the step window they execute in) and computes wait
//!   for their inbound data, so transfer and kernel serialize within the
//!   window; helper results pay their wire time. Reproduces the lock-step
//!   engine (`engine::simulate_attention`) with `overlap = false`
//!   *exactly*.
//! * `depth = d >= 1` — prefetch: a transfer consumed at step `t` may be
//!   issued up to `d` steps early (release at barrier `t - d`); computes
//!   treat prefetchable inbound data (kv / q) as already resident, per
//!   the paper's §3.2 second-stream model, and helper results pipeline
//!   into the next kernel at zero exposed wire time. `depth = 1`
//!   reproduces the lock-step engine with `overlap = true` exactly;
//!   larger depths are never slower and hide more latency when a link is
//!   slow relative to a kernel.
//!
//! ## Dataflow plans (baselines)
//!
//! Plans with `lockstep = false` (Ring Attention's rotating pipeline,
//! Ulysses' all-to-all) have no barriers and no prefetch convention:
//! every dependency edge is honored and overlap *emerges* from the DAG —
//! a transfer runs concurrently with any compute it does not gate.

use crate::config::ClusterSpec;
use crate::coordinator::plan::{Kernel, Plan, PlanOp};
use crate::simulator::engine::AttnCost;

/// Event-engine knobs. `prefetch_depth` only affects lock-step plans.
#[derive(Clone, Copy, Debug)]
pub struct EventOpts {
    pub prefetch_depth: usize,
}

impl Default for EventOpts {
    fn default() -> Self {
        EventOpts { prefetch_depth: 1 }
    }
}

/// Per-op timing plus the aggregate accounting the reports use.
#[derive(Clone, Debug)]
pub struct EventResult {
    /// Wall-clock of the whole plan.
    pub total_s: f64,
    /// Total bytes moved (every transfer, even fully hidden ones).
    pub comm_bytes: f64,
    /// Sum over workers of compute-stream busy time.
    pub busy_s: f64,
    /// Start time of each op, indexed by `OpId`.
    pub op_start: Vec<f64>,
    /// Finish time of each op, indexed by `OpId`.
    pub op_finish: Vec<f64>,
    pub n_workers: usize,
}

impl EventResult {
    /// Fraction of worker-slots spent neither computing (Fig. 1 metric).
    pub fn idle_fraction(&self) -> f64 {
        let denom = self.total_s * self.n_workers as f64;
        if denom == 0.0 {
            return 0.0;
        }
        1.0 - self.busy_s / denom
    }
}

fn kernel_seconds(kernel: &Kernel, cost: &AttnCost) -> f64 {
    match kernel {
        Kernel::AttnDiag => cost.pair_diag_s,
        Kernel::AttnFull => cost.pair_full_s,
        Kernel::Rescale => cost.rescale_s,
        Kernel::Accum => 0.0,
        Kernel::Raw(s) => *s,
    }
}

/// Simulate a plan on a cluster. `cost` resolves the kernel/payload cost
/// classes; its `overlap` flag is ignored here — overlap is the plan DAG
/// plus `opts.prefetch_depth`.
pub fn simulate_plan(
    plan: &Plan,
    cluster: &ClusterSpec,
    cost: &AttnCost,
    opts: &EventOpts,
) -> EventResult {
    let p = plan.n_workers;
    let depth = opts.prefetch_depth;
    let overlap = depth >= 1;
    let n_ops = plan.ops.len();

    let mut compute_tail = vec![0.0f64; p];
    let mut comm_tail = vec![0.0f64; p];
    let mut op_start = vec![0.0f64; n_ops];
    let mut op_finish = vec![0.0f64; n_ops];
    // barrier[t] = completion time of every op with step <= t
    let mut barrier = vec![0.0f64; plan.n_steps.max(1)];
    let mut cur_step = 0usize;
    let mut running_max = 0.0f64;
    let mut comm_bytes = 0.0f64;
    let mut busy_s = 0.0f64;

    for node in &plan.ops {
        if plan.lockstep && node.step > cur_step {
            for t in cur_step..node.step {
                barrier[t] = running_max;
            }
            cur_step = node.step;
        }
        // released-at barrier index: computes and mid-step products are
        // bound to the previous step's barrier; prefetchable transfers may
        // run up to `depth` steps ahead
        let release = if plan.lockstep {
            let back = match &node.op {
                PlanOp::Xfer { payload, .. } if payload.prefetchable() => depth.max(1),
                _ => 1,
            };
            if node.step >= back {
                barrier[node.step - back]
            } else {
                0.0
            }
        } else {
            0.0
        };

        let mut ready = release;
        for &d in &node.deps {
            // the prefetch contract: under overlap, a compute kernel's
            // prefetchable inputs arrived in an earlier window (the
            // barrier guarantees it); the transfer's cost lives on the
            // comm stream instead of gating the kernel
            let skip = plan.lockstep
                && overlap
                && matches!(
                    node.op,
                    PlanOp::Compute { kernel: Kernel::AttnDiag | Kernel::AttnFull, .. }
                )
                && matches!(
                    &plan.ops[d].op,
                    PlanOp::Xfer { payload, .. } if payload.prefetchable()
                );
            if !skip {
                ready = ready.max(op_finish[d]);
            }
        }

        let (dur, stream_tail): (f64, &mut f64) = match &node.op {
            PlanOp::Compute { kernel, .. } => {
                let s = kernel_seconds(kernel, cost);
                busy_s += s;
                (s, &mut compute_tail[node.worker])
            }
            PlanOp::Xfer { src, dst, payload } => {
                let bytes = payload.bytes(cost);
                comm_bytes += bytes;
                let s = if bytes <= 0.0 {
                    0.0
                } else if plan.lockstep && overlap && !payload.prefetchable() {
                    // helper results / grad returns pipeline into the next
                    // kernel on the copy stream (the lock-step engine's
                    // §3.2 convention): no exposed wire time. Dataflow
                    // plans always pay real wire time.
                    0.0
                } else {
                    let (bw, lat) = cluster.link(*src, *dst);
                    lat + bytes / bw
                };
                (s, &mut comm_tail[node.worker])
            }
        };

        let start = ready.max(*stream_tail);
        let finish = start + dur;
        *stream_tail = finish;
        op_start[node.id] = start;
        op_finish[node.id] = finish;
        running_max = running_max.max(finish);
    }

    EventResult {
        total_s: running_max,
        comm_bytes,
        busy_s,
        op_start,
        op_finish,
        n_workers: p,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::plan::Pass;
    use crate::coordinator::Schedule;
    use crate::simulator::engine::simulate_attention;

    fn cost(overlap: bool) -> AttnCost {
        AttnCost {
            pair_full_s: 1e-3,
            pair_diag_s: 0.5e-3,
            rescale_s: 1e-5,
            kv_bytes: 1e6,
            q_bytes: 0.5e6,
            result_bytes: 0.6e6,
            overlap,
        }
    }

    fn rel_close(a: f64, b: f64) -> bool {
        (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1e-30)
    }

    #[test]
    fn matches_lockstep_engine_small() {
        let cluster = ClusterSpec::dgx_2x8();
        for p in [1usize, 2, 3, 8, 16] {
            for kind in [
                crate::coordinator::ScheduleKind::Ring,
                crate::coordinator::ScheduleKind::Balanced,
            ] {
                let s = Schedule::build(kind, p);
                let plan = Plan::from_schedule(&s, Pass::Forward);
                let with = simulate_attention(&s, &cluster, &cost(true));
                let ev =
                    simulate_plan(&plan, &cluster, &cost(true), &EventOpts { prefetch_depth: 1 });
                assert!(
                    rel_close(ev.total_s, with.total_s),
                    "{kind:?} P={p} overlap: {} vs {}",
                    ev.total_s,
                    with.total_s
                );
                let without = simulate_attention(&s, &cluster, &cost(false));
                let ev0 =
                    simulate_plan(&plan, &cluster, &cost(false), &EventOpts { prefetch_depth: 0 });
                assert!(
                    rel_close(ev0.total_s, without.total_s),
                    "{kind:?} P={p} serial: {} vs {}",
                    ev0.total_s,
                    without.total_s
                );
            }
        }
    }

    #[test]
    fn deeper_prefetch_never_slower() {
        let cluster = ClusterSpec::dgx_2x8();
        let s = Schedule::balanced(16);
        let plan = Plan::from_schedule(&s, Pass::Forward);
        let base =
            simulate_plan(&plan, &cluster, &cost(true), &EventOpts { prefetch_depth: 1 }).total_s;
        let mut prev = base;
        for d in [2usize, 4, 8] {
            let t =
                simulate_plan(&plan, &cluster, &cost(true), &EventOpts { prefetch_depth: d })
                    .total_s;
            assert!(t <= prev + 1e-12, "depth {d}: {t} > {prev}");
            prev = t;
        }
    }

    #[test]
    fn deep_prefetch_hides_slow_links() {
        // make kv transfers expensive relative to kernels: depth 1 is
        // comm-bound, a deeper pipeline pulls transfers forward
        let cluster = ClusterSpec::dgx_2x8();
        let c = AttnCost { kv_bytes: 60e6, ..cost(true) };
        let plan = Plan::from_schedule(&Schedule::ring(16), Pass::Forward);
        let d1 = simulate_plan(&plan, &cluster, &c, &EventOpts { prefetch_depth: 1 }).total_s;
        let d8 = simulate_plan(&plan, &cluster, &c, &EventOpts { prefetch_depth: 8 }).total_s;
        assert!(d8 < d1 * 0.95, "depth 8 {d8} should beat depth 1 {d1}");
    }

    #[test]
    fn dataflow_ring_attention_overlaps() {
        // compute-bound regime: wall-clock ~= diag + (P-1) * full per
        // worker; the rotating transfers hide entirely
        let cluster = ClusterSpec::dgx_1x8();
        let p = 8;
        let c = AttnCost { kv_bytes: 1e3, ..cost(true) };
        let plan = Plan::ring_attention(p);
        let r = simulate_plan(&plan, &cluster, &c, &EventOpts::default());
        let expect = c.pair_diag_s + (p - 1) as f64 * c.pair_full_s;
        assert!(rel_close(r.total_s, expect), "{} vs {expect}", r.total_s);
        // comm-bound regime: the hop chain dominates
        let cc = AttnCost { kv_bytes: 1e9, pair_full_s: 1e-6, pair_diag_s: 1e-6, ..cost(true) };
        let r2 = simulate_plan(&plan, &cluster, &cc, &EventOpts::default());
        assert!(r2.total_s > (p - 1) as f64 * (1e9 / cluster.intra_bw));
    }

    #[test]
    fn accounting_shape() {
        let cluster = ClusterSpec::dgx_1x8();
        let s = Schedule::balanced(8);
        let plan = Plan::from_schedule(&s, Pass::Forward);
        let r = simulate_plan(&plan, &cluster, &cost(true), &EventOpts::default());
        assert_eq!(r.op_start.len(), plan.n_ops());
        assert!(r.busy_s > 0.0 && r.total_s > 0.0);
        assert!((0.0..1.0).contains(&r.idle_fraction()));
        // starts never precede deps' finishes for honored edges: spot
        // check rescales (always honored)
        for n in &plan.ops {
            if matches!(n.op, PlanOp::Compute { kernel: Kernel::Rescale, .. }) {
                for &d in &n.deps {
                    assert!(r.op_start[n.id] >= r.op_finish[d] - 1e-15);
                }
            }
        }
    }
}
