//! Tiny bench harness (criterion is unavailable offline): warmup + timed
//! iterations with mean / p50 / p95 / stddev reporting, used by both
//! `cargo bench` targets.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub std_ns: f64,
}

impl BenchStats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn report(&self) -> String {
        fn fmt(ns: f64) -> String {
            if ns < 1e3 {
                format!("{ns:.0} ns")
            } else if ns < 1e6 {
                format!("{:.2} µs", ns / 1e3)
            } else if ns < 1e9 {
                format!("{:.2} ms", ns / 1e6)
            } else {
                format!("{:.3} s", ns / 1e9)
            }
        }
        format!(
            "{:<44} mean {:>10}  p50 {:>10}  p95 {:>10}  (±{:>9}, n={})",
            self.name,
            fmt(self.mean_ns),
            fmt(self.p50_ns),
            fmt(self.p95_ns),
            fmt(self.std_ns),
            self.iters
        )
    }
}

/// Run `f` for `warmup` unmeasured + `iters` measured iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean_ns: mean,
        p50_ns: samples[n / 2],
        p95_ns: samples[((n as f64 * 0.95) as usize).min(n - 1)],
        std_ns: var.sqrt(),
    }
}

/// Keep a value from being optimized away.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures() {
        let s = bench("spin", 2, 16, || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.p50_ns <= s.p95_ns);
        assert_eq!(s.iters, 16);
        assert!(!s.report().is_empty());
    }
}
