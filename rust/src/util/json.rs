//! Minimal JSON parser — the environment is fully offline (no serde), and
//! the manifest contract is small, so a ~200-line recursive-descent parser
//! is the honest dependency-free substrate.
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null). Numbers are parsed as f64; the manifest only
//! carries integers that fit exactly.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null for missing keys.
    pub fn at(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.get(key).unwrap_or(&NULL)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Shape-style arrays: `[4, 32, 16]` -> `vec![4, 32, 16]`.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

/// Escape a string for embedding in a JSON document (the emit-side
/// counterpart of this parser, shared by every hand-rolled JSON writer in
/// the crate): backslash, quote, and control characters.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // copy a run of plain bytes at once
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    let _ = c;
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
        assert_eq!(Json::parse("-1.5").unwrap().as_f64(), Some(-1.5));
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"a\\nb\"").unwrap().as_str(), Some("a\nb"));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x", "c": false}], "d": {}}"#).unwrap();
        assert_eq!(j.at("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.at("a").as_arr().unwrap()[2].at("b").as_str(), Some("x"));
        assert_eq!(j.at("d").as_obj().unwrap().len(), 0);
        assert_eq!(j.at("missing"), &Json::Null);
    }

    #[test]
    fn shape_vec() {
        let j = Json::parse("[4, 32, 16]").unwrap();
        assert_eq!(j.as_usize_vec(), Some(vec![4, 32, 16]));
        assert_eq!(Json::parse("[1, 2.5]").unwrap().as_usize_vec(), None);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u00e9\"").unwrap().as_str(), Some("é"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{'a': 1}").is_err());
    }

    #[test]
    fn escape_roundtrips_through_parse() {
        let raw = "a\"b\\c\nd\te\u{1}";
        let doc = format!("\"{}\"", escape(raw));
        assert_eq!(Json::parse(&doc).unwrap().as_str(), Some(raw));
    }

    #[test]
    fn whitespace_tolerant() {
        let j = Json::parse(" {\n \"k\" :\t[ ] } ").unwrap();
        assert_eq!(j.at("k").as_arr().unwrap().len(), 0);
    }
}
