//! Deterministic xoshiro256++ RNG: synthetic data, property-test sweeps,
//! and parameter init all flow from explicit seeds so every run reproduces.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        // splitmix64 expansion of the seed
        let mut x = seed.wrapping_add(0x9e3779b97f4a7c15);
        let mut next = || {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-7);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(42);
        let xs: Vec<f32> = (0..20000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        let mut seen = [false; 5];
        for _ in 0..200 {
            seen[r.below(5)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
