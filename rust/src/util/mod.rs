//! Dependency-free substrates: JSON parsing, a deterministic RNG, and a
//! tiny bench harness (the environment is offline; serde/rand/criterion are
//! not available, so these are built here and tested like everything else).

pub mod bench;
pub mod json;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
