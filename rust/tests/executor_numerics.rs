//! End-to-end numerics: the distributed executor (P threads, real PJRT
//! kernels, channel comm) must reproduce the monolithic full-attention
//! oracle — forward outputs, logsumexp, and all three gradients — for both
//! schedules, several worker counts, and the GQA variant.
//!
//! Requires `make artifacts` (tiny configs) to have run.

use std::path::PathBuf;

use distflash::coordinator::{DistAttnResult, RunSpec, ScheduleKind, Session, Workload};
use distflash::runtime::{Runtime, Tensor, Value};
use distflash::util::Rng;

fn artifact_dir(cfg: &str) -> PathBuf {
    let root = std::env::var("CARGO_MANIFEST_DIR").unwrap();
    PathBuf::from(root).join("artifacts").join(cfg)
}

fn have(cfg: &str) -> bool {
    let ok = artifact_dir(cfg).join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts/{cfg} missing (run `make artifacts`)");
    }
    ok
}


/// Distributed attention via the Session pipeline (the legacy
/// `run_dist_attention` call sites, spec-driven).
#[allow(clippy::too_many_arguments)]
fn dist(
    dir: &std::path::Path,
    kind: ScheduleKind,
    p: usize,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    do_: Option<&Tensor>,
) -> DistAttnResult {
    let mut spec = RunSpec::pjrt(dir, kind);
    spec.workload = Some(Workload::from_tensors(q, k, p));
    spec.n_workers = p;
    let mut session = Session::new(spec).unwrap();
    session.execute_with(q, k, v, do_).unwrap();
    session.take_run().unwrap().result
}

struct Case {
    q: Tensor,
    k: Tensor,
    v: Tensor,
    do_: Tensor,
    o_ref: Tensor,
    lse_ref: Tensor,
}

/// Build random inputs and evaluate the monolithic oracle artifact.
fn make_case(cfg: &str, seed: u64) -> Case {
    let rt = Runtime::load(&artifact_dir(cfg)).unwrap();
    let mc = rt.manifest().config.clone();
    let (h, kvh, n, d) = (mc.n_heads, mc.n_kv_heads, mc.seq_len, mc.head_dim);
    let mut rng = Rng::new(seed);
    let q = Tensor::new(vec![h, n, d], rng.normal_vec(h * n * d));
    let k = Tensor::new(vec![kvh, n, d], rng.normal_vec(kvh * n * d));
    let v = Tensor::new(vec![kvh, n, d], rng.normal_vec(kvh * n * d));
    let do_ = Tensor::new(vec![h, n, d], rng.normal_vec(h * n * d));
    let out = rt
        .run(
            "full_attn_ref",
            &[
                Value::F32(q.clone()),
                Value::F32(k.clone()),
                Value::F32(v.clone()),
            ],
        )
        .unwrap();
    Case {
        q,
        k,
        v,
        do_,
        o_ref: out[0].clone(),
        lse_ref: out[1].clone(),
    }
}

fn check_forward_backward(cfg: &str, kind: ScheduleKind, seed: u64) {
    let case = make_case(cfg, seed);
    let rt = Runtime::load(&artifact_dir(cfg)).unwrap();
    let p = rt.manifest().config.n_workers;
    let res = dist(
        &artifact_dir(cfg),
        kind,
        p,
        &case.q,
        &case.k,
        &case.v,
        Some(&case.do_),
    );

    let o_err = res.o.max_abs_diff(&case.o_ref);
    let lse_err = res.lse.max_abs_diff(&case.lse_ref);
    assert!(o_err < 2e-5, "{cfg} {kind:?}: forward o err {o_err}");
    assert!(lse_err < 2e-5, "{cfg} {kind:?}: lse err {lse_err}");

    let (dq, dk, dv) = res.grads.unwrap();
    for (name, g) in [("dq", &dq), ("dk", &dk), ("dv", &dv)] {
        assert!(
            g.data().iter().all(|x| x.is_finite()),
            "{cfg} {kind:?}: {name} has non-finite entries"
        );
        assert!(g.l2_norm() > 1e-3, "{cfg} {kind:?}: {name} suspiciously zero");
    }
}

#[test]
fn forward_matches_oracle_tiny_ring() {
    if !have("tiny") {
        return;
    }
    check_forward_backward("tiny", ScheduleKind::Ring, 1);
}

#[test]
fn forward_matches_oracle_tiny_balanced() {
    if !have("tiny") {
        return;
    }
    check_forward_backward("tiny", ScheduleKind::Balanced, 2);
}

#[test]
fn forward_matches_oracle_gqa_both() {
    if !have("tiny-gqa") {
        return;
    }
    check_forward_backward("tiny-gqa", ScheduleKind::Ring, 3);
    check_forward_backward("tiny-gqa", ScheduleKind::Balanced, 4);
}

#[test]
fn forward_matches_oracle_odd_workers() {
    // P = 3 exercises the odd-P balanced schedule (zero idle, helpers at
    // the final step — the case the paper's Alg. 2 pseudocode mis-states)
    if !have("tiny-p3") {
        return;
    }
    check_forward_backward("tiny-p3", ScheduleKind::Ring, 5);
    check_forward_backward("tiny-p3", ScheduleKind::Balanced, 6);
}

#[test]
fn ring_and_balanced_grads_agree() {
    if !have("tiny") {
        return;
    }
    let case = make_case("tiny", 7);
    let dir = artifact_dir("tiny");
    let p = 4;
    let a = dist(&dir, ScheduleKind::Ring, p, &case.q, &case.k, &case.v, Some(&case.do_));
    let b = dist(&dir, ScheduleKind::Balanced, p, &case.q, &case.k, &case.v, Some(&case.do_));
    let (adq, adk, adv) = a.grads.unwrap();
    let (bdq, bdk, bdv) = b.grads.unwrap();
    assert!(adq.max_abs_diff(&bdq) < 2e-5);
    assert!(adk.max_abs_diff(&bdk) < 2e-5);
    assert!(adv.max_abs_diff(&bdv) < 2e-5);
    assert!(b.comm_bytes > 0 && a.comm_bytes > 0);
}

#[test]
fn backward_dq_of_first_chunk_is_local() {
    // dq for the first chunk only flows from its diagonal pair (causality),
    // so a standalone P=1 run on chunk 0 must reproduce the full run's dq0.
    if !have("tiny") {
        return;
    }
    let case = make_case("tiny", 8);
    let dir = artifact_dir("tiny");
    let full = dist(&dir, ScheduleKind::Balanced, 4, &case.q, &case.k, &case.v, Some(&case.do_));

    let qs = case.q.chunk_axis1(4);
    let ks = case.k.chunk_axis1(4);
    let vs = case.v.chunk_axis1(4);
    let dos = case.do_.chunk_axis1(4);
    let solo = dist(&dir, ScheduleKind::Ring, 1, &qs[0], &ks[0], &vs[0], Some(&dos[0]));
    let full_o = full.o.chunk_axis1(4);
    assert!(full_o[0].max_abs_diff(&solo.o) < 2e-5);
    let (dq_full, _, _) = full.grads.unwrap();
    let (dq_solo, _, _) = solo.grads.unwrap();
    assert!(dq_full.chunk_axis1(4)[0].max_abs_diff(&dq_solo) < 2e-5);
}

#[test]
fn comm_volume_halved_by_causality() {
    // §D: forward kv comm is Nd (not 2Nd) because workers only fetch kv
    // from earlier chunks. Check the executor's actual byte counters:
    // ring fwd kv bytes = (# cross pairs) * chunk kv bytes.
    if !have("tiny") {
        return;
    }
    let case = make_case("tiny", 9);
    let dir = artifact_dir("tiny");
    let rt = Runtime::load(&dir).unwrap();
    let mc = rt.manifest().config.clone();
    let p = mc.n_workers;
    let res = dist(&dir, ScheduleKind::Ring, p, &case.q, &case.k, &case.v, None);
    let chunk_kv_bytes = (2 * mc.n_kv_heads * mc.chunk_len * mc.head_dim * 4) as u64;
    let expect = (p * (p - 1) / 2) as u64 * chunk_kv_bytes;
    assert_eq!(res.comm_bytes, expect, "ring fwd comm bytes");
}
