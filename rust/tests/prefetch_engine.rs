//! Prefetch-engine + zero-copy fabric stress tests.
//!
//! These run the *real* threaded executor end to end on the pure-host
//! kernel backend (no PJRT, no artifacts), which is what makes the
//! numerics pinnable on a bare checkout:
//!
//! * the distributed forward/backward must reproduce the monolithic
//!   host `full_attn_ref` oracle (and its saved-statistics backward);
//! * the depth-0 (fully blocking) path and the deep-prefetch path must be
//!   **bit-identical** — posting receives only changes message transport,
//!   never kernel order — including under adversarial cross-call
//!   interleaving (P=8 workers racing through stacked attention calls at
//!   their own pace, so late ranks find early ranks' future-call traffic
//!   in their mailboxes);
//! * the stash must stay FIFO per (sender, tag) under shuffled arrival.

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use distflash::coordinator::comm::{build_network, Tag};
use distflash::coordinator::executor::{AttnCtx, RunTrace};
use distflash::coordinator::{
    BackendSpec, Pass, Plan, RunSpec, Schedule, ScheduleKind, Session,
};
use distflash::runtime::{HostKernels, Kernels, Tensor, Value};
use distflash::util::Rng;

/// Lower a schedule through the Session pipeline (the `build_plans`
/// replacement).
fn plans(kind: ScheduleKind, p: usize) -> (Arc<Plan>, Arc<Plan>) {
    Session::new(RunSpec::plans_only(kind, p))
        .unwrap()
        .plans()
        .unwrap()
}

const H: usize = 4;
const KVH: usize = 2;
const C: usize = 12;
const D: usize = 8;

fn inputs(p: usize, seed: u64) -> (Tensor, Tensor, Tensor, Tensor) {
    let n = p * C;
    let mut rng = Rng::new(seed);
    (
        Tensor::new(vec![H, n, D], rng.normal_vec(H * n * D)),
        Tensor::new(vec![KVH, n, D], rng.normal_vec(KVH * n * D)),
        Tensor::new(vec![KVH, n, D], rng.normal_vec(KVH * n * D)),
        Tensor::new(vec![H, n, D], rng.normal_vec(H * n * D)),
    )
}

fn with_depth(plan: &Arc<Plan>, depth: usize) -> Arc<Plan> {
    let mut p = (**plan).clone();
    p.prefetch_depth = depth;
    Arc::new(p)
}

/// Run `layers` stacked attention calls (fwd + bwd each, distinct call
/// ids) through the real executor on every rank, at each rank's own pace —
/// the adversarial interleaving: a fast rank's call-k+1 traffic lands in a
/// slow rank's mailbox while it is still inside call k. `skew` staggers
/// rank start times to force exactly that. Returns every per-rank output
/// tensor in a deterministic order.
fn run_layers(
    fwd: &Arc<Plan>,
    bwd: &Arc<Plan>,
    layers: usize,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    do_: &Tensor,
    skew: bool,
) -> Vec<Vec<Tensor>> {
    let p = fwd.n_workers;
    let qs = q.chunk_axis1(p);
    let ks = k.chunk_axis1(p);
    let vs = v.chunk_axis1(p);
    let dos = do_.chunk_axis1(p);
    let comms = build_network(p);
    let mut handles = Vec::new();
    for (rank, mut comm) in comms.into_iter().enumerate() {
        let fwd = fwd.clone();
        let bwd = bwd.clone();
        let (q, k, v, d) = (
            qs[rank].clone(),
            ks[rank].clone(),
            vs[rank].clone(),
            dos[rank].clone(),
        );
        handles.push(thread::spawn(move || -> Vec<Tensor> {
            if skew {
                thread::sleep(Duration::from_millis(3 * rank as u64));
            }
            let kernels = HostKernels::default();
            let mut out = Vec::new();
            for layer in 0..layers {
                let (o, lse) = {
                    let mut ctx = AttnCtx {
                        rank,
                        runtime: &kernels,
                        comm: &mut comm,
                        plan: &fwd,
                        call_id: (2 * layer) as u32,
                        epoch: None,
                        trace: RunTrace::default(),
                    };
                    ctx.forward(&q, &k, &v).expect("forward failed")
                };
                let (dq, dk, dv) = {
                    let mut ctx = AttnCtx {
                        rank,
                        runtime: &kernels,
                        comm: &mut comm,
                        plan: &bwd,
                        call_id: (2 * layer + 1) as u32,
                        epoch: None,
                        trace: RunTrace::default(),
                    };
                    ctx.backward(&q, &k, &v, &o, &lse, &d).expect("backward failed")
                };
                out.extend([o, lse, dq, dk, dv]);
            }
            out
        }));
    }
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

#[test]
fn host_executor_matches_oracle_p8_both_schedules() {
    let p = 8;
    let (q, k, v, do_) = inputs(p, 42);
    let oracle = HostKernels::default()
        .run(
            "full_attn_ref",
            &[
                Value::F32(q.clone()),
                Value::F32(k.clone()),
                Value::F32(v.clone()),
            ],
        )
        .unwrap();
    // monolithic causal backward over the whole sequence (one diag kernel
    // spanning N) — the gradient oracle
    let grads_ref = HostKernels::default()
        .run(
            "attn_bwd_diag",
            &[
                Value::F32(q.clone()),
                Value::F32(k.clone()),
                Value::F32(v.clone()),
                Value::F32(oracle[0].clone()),
                Value::F32(oracle[1].clone()),
                Value::F32(do_.clone()),
            ],
        )
        .unwrap();

    for kind in [ScheduleKind::Ring, ScheduleKind::Balanced] {
        let (fwd, bwd) = plans(kind, p);
        let spec = RunSpec::for_plans(&fwd, BackendSpec::HostRef, &q, &k);
        let mut session = Session::with_plans(spec, fwd, bwd).unwrap();
        session.execute_with(&q, &k, &v, Some(&do_)).unwrap();
        let run = session.take_run().unwrap();
        let o_err = run.result.o.max_abs_diff(&oracle[0]);
        let lse_err = run.result.lse.max_abs_diff(&oracle[1]);
        assert!(o_err < 1e-4, "{kind:?}: o err {o_err}");
        assert!(lse_err < 1e-4, "{kind:?}: lse err {lse_err}");
        let (dq, dk, dv) = run.result.grads.unwrap();
        assert!(dq.max_abs_diff(&grads_ref[0]) < 1e-3, "{kind:?}: dq diverges");
        assert!(dk.max_abs_diff(&grads_ref[1]) < 1e-3, "{kind:?}: dk diverges");
        assert!(dv.max_abs_diff(&grads_ref[2]) < 1e-3, "{kind:?}: dv diverges");
        assert!(run.result.comm_bytes > 0);
    }
}

#[test]
fn depth0_and_deep_prefetch_bit_identical_under_interleaving() {
    let p = 8;
    let layers = 4;
    let (q, k, v, do_) = inputs(p, 7);
    let (fwd, bwd) = plans(ScheduleKind::Balanced, p);
    // depth 0: no drains, every receive blocks at point of use
    let blocking = run_layers(
        &with_depth(&fwd, 0),
        &with_depth(&bwd, 0),
        layers,
        &q,
        &k,
        &v,
        &do_,
        false,
    );
    // deep prefetch + skewed rank starts: maximal stash traffic, future
    // calls' messages drained while earlier calls are still in flight
    let prefetched = run_layers(
        &with_depth(&fwd, 8),
        &with_depth(&bwd, 8),
        layers,
        &q,
        &k,
        &v,
        &do_,
        true,
    );
    assert_eq!(blocking.len(), prefetched.len());
    for (rank, (a, b)) in blocking.iter().zip(&prefetched).enumerate() {
        assert_eq!(a.len(), b.len());
        for (i, (ta, tb)) in a.iter().zip(b).enumerate() {
            assert_eq!(
                ta, tb,
                "rank {rank} output {i}: prefetch path is not bit-identical"
            );
        }
    }
    // and the executor's own default (depth 1) agrees too
    let default = run_layers(&fwd, &bwd, layers, &q, &k, &v, &do_, false);
    assert_eq!(blocking, default, "depth-1 drain path diverged");
}

#[test]
fn stash_fifo_under_shuffled_arrival_p8() {
    // every rank sends 3 messages per (peer, tag) across 4 tags, in a
    // rank-dependent shuffled order; receivers drain (racing the senders)
    // then receive in canonical order — per-(sender, tag) FIFO must hold
    let p = 8;
    let comms = build_network(p);
    let handles: Vec<_> = comms
        .into_iter()
        .map(|mut comm| {
            thread::spawn(move || {
                let rank = comm.rank;
                // lanes = (peer, tag); interleave lanes randomly but keep
                // each lane's own send order (FIFO is a per-lane contract)
                let mut lanes: Vec<(usize, u32, u32)> = Vec::new();
                for peer in 0..p {
                    if peer == rank {
                        continue;
                    }
                    for t in 0..4u32 {
                        lanes.push((peer, t, 0));
                    }
                }
                let mut rng = Rng::new(rank as u64 + 1);
                let mut remaining = lanes.len() * 3;
                while remaining > 0 {
                    let li = rng.below(lanes.len());
                    let (peer, t, s) = lanes[li];
                    if s >= 3 {
                        continue;
                    }
                    // seq carried in the payload; tag identifies the lane
                    let val = (rank * 1000 + t as usize * 10 + s as usize) as f32;
                    comm.send(peer, Tag::new(9, t, 0), vec![Tensor::scalar(val)]).unwrap();
                    lanes[li].2 += 1;
                    remaining -= 1;
                }
                comm.drain_pending();
                for peer in 0..p {
                    if peer == rank {
                        continue;
                    }
                    for t in 0..4u32 {
                        for s in 0..3 {
                            let got = comm.recv(peer, Tag::new(9, t, 0)).unwrap()[0].as_scalar();
                            let want = (peer * 1000 + t as usize * 10 + s) as f32;
                            assert_eq!(got, want, "rank {rank} lane ({peer},{t}) seq {s}");
                        }
                    }
                }
                comm.barrier(77).unwrap();
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn executor_rejects_dataflow_plans_at_index_time() {
    let plan = Plan::ring_attention(4);
    let comms = build_network(4);
    let mut comm = comms.into_iter().next().unwrap();
    let kernels = HostKernels::default();
    let ctx = AttnCtx {
        rank: 0,
        runtime: &kernels,
        comm: &mut comm,
        plan: &plan,
        call_id: 0,
        epoch: None,
        trace: RunTrace::default(),
    };
    let err = ctx.check_and_index(Pass::Forward).unwrap_err();
    assert!(format!("{err}").contains("schedule-lowered"));
    // and a pass mismatch is caught before any communication
    let lowered = Schedule::balanced(4).lower(Pass::Forward);
    let ctx = AttnCtx {
        rank: 0,
        runtime: &kernels,
        comm: &mut comm,
        plan: &lowered,
        call_id: 0,
        epoch: None,
        trace: RunTrace::default(),
    };
    assert!(ctx.check_and_index(Pass::Backward).is_err());
}
