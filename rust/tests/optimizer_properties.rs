//! Plan-optimizer property suite.
//!
//! Every optimization pass (role flipping at lowering, placement
//! permutation, prefetch-depth choice) must preserve the IR's semantic
//! invariants — `Plan::validate` / `validate_lowered`, the exact causal
//! pair set, per-(src, dst) wire-tag uniqueness — while never making the
//! simulated makespan worse than the default lowering, on every cluster
//! preset. The search itself must be deterministic given a seed, and the
//! pre-resolved `PlanSim` fast path must agree exactly with the one-shot
//! `simulate_plan`.

use std::collections::HashSet;

use distflash::baselines::{attn_cost_bwd, attn_cost_fwd};
use distflash::config::{ClusterSpec, PaperModel};
use distflash::coordinator::{
    optimize_plan, optimize_schedule, LowerOpts, OptimizeOpts, Pass, Plan, Schedule, ScheduleKind,
};
use distflash::simulator::{simulate_plan, AttnCost, EventOpts, PlanSim};

fn presets() -> Vec<(&'static str, ClusterSpec)> {
    vec![
        ("1x8", ClusterSpec::dgx_1x8()),
        ("2x8", ClusterSpec::dgx_2x8()),
        ("16x40g", ClusterSpec::cluster_16x40g()),
    ]
}

fn test_cost() -> AttnCost {
    AttnCost {
        pair_full_s: 1e-3,
        pair_diag_s: 0.5e-3,
        rescale_s: 1e-5,
        kv_bytes: 1e6,
        q_bytes: 4e6,
        result_bytes: 4.4e6,
        overlap: true,
    }
}

/// Sorted causal pair set, ignoring which (step, worker) slot computes it
/// — the semantic content the optimizer must not change.
fn pair_set(plan: &Plan) -> Vec<(usize, usize)> {
    let mut pairs: Vec<(usize, usize)> = plan
        .computed_pairs()
        .into_iter()
        .map(|(pr, _)| pr)
        .collect();
    pairs.sort_unstable();
    pairs
}

#[test]
fn flip_lowering_preserves_all_invariants() {
    // flipping every helper step at once is the most invasive rewrite the
    // optimizer can request; it must still be a valid lowering with the
    // same pair coverage, for every P and both passes
    for p in 1..=16 {
        let s = Schedule::balanced(p);
        let all_flipped = LowerOpts { flip_steps: vec![true; s.n_steps()], ..Default::default() };
        for pass in [Pass::Forward, Pass::Backward] {
            let base = Plan::from_schedule(&s, pass);
            let flipped = Plan::from_schedule_opts(&s, pass, &all_flipped);
            flipped
                .validate_lowered()
                .unwrap_or_else(|e| panic!("P={p} {pass:?} flipped: {e}"));
            assert_eq!(pair_set(&base), pair_set(&flipped), "P={p} {pass:?}");
            // wire tags stay unique per (src, dst)
            let mut seen = HashSet::new();
            for t in flipped.wire_tags(7) {
                assert!(seen.insert(t), "P={p} {pass:?}: duplicate tag {t:?}");
            }
        }
    }
}

#[test]
fn flipped_steps_drop_q_and_result_traffic() {
    let s = Schedule::balanced(16);
    let all_flipped = LowerOpts { flip_steps: vec![true; s.n_steps()], ..Default::default() };
    let base = Plan::from_schedule(&s, Pass::Forward);
    let flipped = Plan::from_schedule_opts(&s, Pass::Forward, &all_flipped);
    let cost = test_cost();
    // q bundle (4 MB) + result (4.4 MB) per helper pair are replaced by a
    // kv fetch (1 MB): total bytes must drop
    assert!(
        flipped.total_bytes(&cost) < base.total_bytes(&cost),
        "flipped {} vs base {}",
        flipped.total_bytes(&cost),
        base.total_bytes(&cost)
    );
    // and the op count shrinks (no helper-result transfer, no rescale)
    assert!(flipped.n_ops() < base.n_ops());
}

#[test]
fn optimizer_preserves_invariants_on_every_preset() {
    let opts = OptimizeOpts::default();
    for (name, cluster) in presets() {
        let p = cluster.n_gpus();
        for kind in [ScheduleKind::Balanced, ScheduleKind::Ring] {
            let s = Schedule::build(kind, p);
            for pass in [Pass::Forward, Pass::Backward] {
                let base = Plan::from_schedule(&s, pass);
                let o = optimize_schedule(&s, pass, &cluster, &test_cost(), &opts);
                o.plan
                    .validate_lowered()
                    .unwrap_or_else(|e| panic!("{name} {kind:?} {pass:?}: {e}"));
                assert_eq!(
                    pair_set(&base),
                    pair_set(&o.plan),
                    "{name} {kind:?} {pass:?}: pair set changed"
                );
                // placement is a permutation (validate checks distinctness;
                // also pin the length and range here)
                assert_eq!(o.plan.placement.len(), p);
                assert!(o.plan.placement.iter().all(|&g| g < p.max(cluster.n_gpus())));
                // never worse than the default lowering at default depth
                assert!(
                    o.optimized_s <= o.default_s * (1.0 + 1e-9),
                    "{name} {kind:?} {pass:?}: {} -> {}",
                    o.default_s,
                    o.optimized_s
                );
            }
        }
    }
}

#[test]
fn optimize_plan_handles_dataflow_baselines() {
    // placement + depth passes must also run on non-lockstep plans
    let opts = OptimizeOpts::default();
    for (name, cluster) in presets() {
        let plan = Plan::ring_attention(cluster.n_gpus());
        let o = optimize_plan(&plan, &cluster, &test_cost(), &opts);
        o.plan.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(o.flipped_steps.is_empty());
        assert!(
            o.optimized_s <= o.default_s * (1.0 + 1e-9),
            "{name}: {} -> {}",
            o.default_s,
            o.optimized_s
        );
    }
}

#[test]
fn strict_improvement_on_heterogeneous_cluster() {
    // the acceptance case: GQA model on the 2x8 InfiniBand cluster — the
    // q bundle dwarfs the kv fetch, so role flipping + depth autotuning
    // must deliver a strictly faster plan with identical coverage
    let cluster = ClusterSpec::dgx_2x8();
    let model = PaperModel::llama_gqa();
    let p = cluster.n_gpus();
    let s = Schedule::balanced(p);
    for (pass, cost, min_gain) in [
        (Pass::Forward, attn_cost_fwd(&model, &cluster, 2048.0), 0.95),
        (Pass::Backward, attn_cost_bwd(&model, &cluster, 2048.0), 0.90),
    ] {
        let o = optimize_schedule(&s, pass, &cluster, &cost, &OptimizeOpts::default());
        assert!(
            o.optimized_s < o.default_s * min_gain,
            "{pass:?}: expected a real win, got {:.4} -> {:.4} ({:.2}x)",
            o.default_s,
            o.optimized_s,
            o.speedup()
        );
        assert!(!o.flipped_steps.is_empty(), "{pass:?}: flipping should fire");
        o.plan.validate_lowered().unwrap();
        assert_eq!(pair_set(&Plan::from_schedule(&s, pass)), pair_set(&o.plan));
    }
}

#[test]
fn placement_search_is_deterministic_given_seed() {
    let cluster = ClusterSpec::dgx_2x8();
    let s = Schedule::balanced(16);
    let cost = test_cost();
    for seed in [0u64, 7, 42] {
        let opts = OptimizeOpts { seed, ..Default::default() };
        let a = optimize_schedule(&s, Pass::Forward, &cluster, &cost, &opts);
        let b = optimize_schedule(&s, Pass::Forward, &cluster, &cost, &opts);
        assert_eq!(a.plan.placement, b.plan.placement, "seed {seed}");
        assert_eq!(a.flipped_steps, b.flipped_steps, "seed {seed}");
        assert_eq!(a.prefetch_depth, b.prefetch_depth, "seed {seed}");
        assert_eq!(a.optimized_s.to_bits(), b.optimized_s.to_bits(), "seed {seed}");
        assert_eq!(a.sim_calls, b.sim_calls, "seed {seed}");
    }
}

#[test]
fn plan_sim_agrees_with_simulate_plan_exactly() {
    let cluster = ClusterSpec::dgx_2x8();
    let cost = test_cost();
    let plans = vec![
        Plan::from_schedule(&Schedule::balanced(16), Pass::Forward),
        Plan::from_schedule(&Schedule::balanced(13), Pass::Backward),
        Plan::from_schedule(&Schedule::ring(16), Pass::Forward),
        Plan::ring_attention(16),
        Plan::ulysses(8, 1e-3, 2e6, 1e6),
    ];
    for plan in &plans {
        let mut sim = PlanSim::new(plan, &cost);
        for depth in [0usize, 1, 2, 4, 8] {
            let one_shot =
                simulate_plan(plan, &cluster, &cost, &EventOpts { prefetch_depth: depth });
            // repeated reuse of the same scratch must not drift
            for _ in 0..3 {
                let fast = sim.total_s(&cluster, &plan.placement, depth);
                assert_eq!(
                    fast.to_bits(),
                    one_shot.total_s.to_bits(),
                    "{} depth {depth}",
                    plan.name
                );
            }
            let full = sim.run(&cluster, &plan.placement, depth);
            assert_eq!(full.total_s.to_bits(), one_shot.total_s.to_bits());
            assert_eq!(full.comm_bytes.to_bits(), one_shot.comm_bytes.to_bits());
            assert_eq!(full.busy_s.to_bits(), one_shot.busy_s.to_bits());
            assert_eq!(full.op_start, one_shot.op_start, "{} depth {depth}", plan.name);
        }
    }
}

#[test]
fn placement_changes_link_pricing() {
    // the ring schedule's distance-t kv sends make the identity placement
    // cross nodes at *every* step; interleaving ranks across the two nodes
    // keeps even distances intra-node, so in a comm-bound regime the
    // interleaved placement is measurably faster — placement is a real,
    // priced degree of freedom, and the hill climb must find something at
    // least as good as the identity
    let cluster = ClusterSpec::dgx_2x8();
    let cost = AttnCost { kv_bytes: 100e6, ..test_cost() };
    let mut plan = Plan::from_schedule(&Schedule::ring(16), Pass::Forward);
    let base = simulate_plan(&plan, &cluster, &cost, &EventOpts::default()).total_s;
    plan.placement = (0..16).map(|i| (i % 2) * 8 + i / 2).collect();
    plan.validate().unwrap();
    let interleaved = simulate_plan(&plan, &cluster, &cost, &EventOpts::default()).total_s;
    assert!(
        interleaved < base * 0.8,
        "interleaved placement should win the comm-bound ring: {base} vs {interleaved}"
    );
    // and the optimizer's placement search must capture a win of this kind
    let o = optimize_schedule(
        &Schedule::ring(16),
        Pass::Forward,
        &cluster,
        &cost,
        &OptimizeOpts::default(),
    );
    assert!(
        o.optimized_s < o.default_s,
        "placement/depth search should strictly beat identity here: {} vs {}",
        o.default_s,
        o.optimized_s
    );
}

#[test]
fn autotuned_depth_is_a_knee() {
    // depth knee: total within 1% of the best sweep time, and deeper
    // candidate depths never beat it by more than the tolerance
    let cluster = ClusterSpec::dgx_2x8();
    let cost = AttnCost { kv_bytes: 60e6, ..test_cost() };
    let plan = Plan::from_schedule(&Schedule::ring(16), Pass::Forward);
    let opts = OptimizeOpts::default();
    let (depth, total) = distflash::coordinator::autotune_depth(&plan, &cluster, &cost, &opts);
    let best = opts
        .depths
        .iter()
        .map(|&d| simulate_plan(&plan, &cluster, &cost, &EventOpts { prefetch_depth: d }).total_s)
        .fold(f64::INFINITY, f64::min);
    assert!(total <= best * (1.0 + opts.knee_rel_tol) + 1e-15, "{total} vs best {best}");
    // in this comm-bound regime depth 1 is not the knee
    assert!(depth > 1, "expected a deep knee, got {depth}");
}
