//! Varlen (document-packed) invariants.
//!
//! The token-level stack must preserve the IR's semantics end to end:
//! rebalancing conserves tokens and keeps boundaries sane; the sparse
//! lowering is causal-mask-correct on ragged chunks (zero-weight chunk
//! pairs vanish, live work sums to the doc-exact total); the equal-chunk
//! degenerate spec lowers to *bit-identical* ops vs the classic path; the
//! incremental rescorer agrees with a full re-simulation on arbitrary
//! move sequences; and on a skewed Zipf preset the rebalancer clears the
//! acceptance bar (>= 1.2x over pad-to-max within PR 2's sim budget
//! order).

use std::sync::Arc;

use distflash::baselines::{attn_cost_bwd, attn_cost_fwd};
use distflash::config::{ClusterSpec, PaperModel};
use distflash::coordinator::{
    optimize_varlen, ComputeOp, LowerOpts, OptimizeOpts, Pass, Plan, PlanOp, RunSpec, Schedule,
    ScheduleKind, Session, VarlenSpec,
};
use distflash::runtime::Tensor;
use distflash::simulator::{AttnCost, PlanSim};
use distflash::util::Rng;

fn test_cost() -> AttnCost {
    AttnCost {
        pair_full_s: 1e-3,
        pair_diag_s: 0.5e-3,
        rescale_s: 1e-5,
        kv_bytes: 1e6,
        q_bytes: 4e6,
        result_bytes: 4.4e6,
        overlap: true,
    }
}

fn pair_set(plan: &Plan) -> Vec<(usize, usize)> {
    let mut pairs: Vec<(usize, usize)> =
        plan.computed_pairs().into_iter().map(|(pr, _)| pr).collect();
    pairs.sort_unstable();
    pairs
}

#[test]
fn token_conservation_across_rebalancing() {
    let cluster = ClusterSpec::dgx_2x8();
    let p = cluster.n_gpus();
    let spec0 = VarlenSpec::pack_zipf(48, 512 * p, 1.2, 5, p);
    let o = optimize_varlen(
        &Schedule::balanced(p),
        &spec0,
        Pass::Forward,
        &cluster,
        &test_cost(),
        &OptimizeOpts::default(),
    );
    // every boundary move conserved the packed batch exactly
    o.spec.validate().unwrap();
    assert_eq!(o.spec.total_tokens(), spec0.total_tokens());
    assert_eq!(o.spec.doc_lens, spec0.doc_lens);
    assert_eq!(o.spec.n_chunks(), p);
    for w in 0..p {
        assert!(o.spec.chunk_tokens(w) >= 1, "chunk {w} emptied");
    }
    let total: usize = (0..p).map(|w| o.spec.chunk_tokens(w)).sum();
    assert_eq!(total, spec0.total_tokens());
}

#[test]
fn causal_mask_correct_on_ragged_chunks() {
    // two 64-token documents over 4 chunks of 32: chunks {0,1} hold doc 0,
    // chunks {2,3} hold doc 1 — nothing may cross the document boundary
    let spec = VarlenSpec::equal_split(vec![64, 64], 4);
    let lopts = LowerOpts { varlen: Some(Arc::new(spec.clone())), ..Default::default() };
    for pass in [Pass::Forward, Pass::Backward] {
        let plan = Plan::from_schedule_opts(&Schedule::balanced(4), pass, &lopts);
        plan.validate_lowered().unwrap_or_else(|e| panic!("{pass:?}: {e}"));
        // computed pairs are exactly the positive-weight pairs
        let pairs = pair_set(&plan);
        for q in 0..4 {
            for kv in 0..=q {
                assert_eq!(
                    pairs.contains(&(q, kv)),
                    spec.pair_weight(q, kv) > 0.0,
                    "{pass:?}: pair ({q},{kv})"
                );
            }
        }
        // no transfer crosses the doc-disjoint halves
        for n in &plan.ops {
            if let PlanOp::Xfer { src, dst, .. } = &n.op {
                assert_eq!(
                    *src < 2,
                    *dst < 2,
                    "{pass:?}: op {} ships data across unrelated documents",
                    n.id
                );
            }
        }
    }
    // live compute sums to the doc-exact token-pair total
    let cost = AttnCost { rescale_s: 0.0, ..test_cost() };
    let plan = Plan::from_schedule_opts(&Schedule::balanced(4), Pass::Forward, &lopts);
    let busy = PlanSim::new(&plan, &cost).busy_s();
    let c_ref = spec.ref_tokens();
    let want: f64 = spec
        .doc_lens
        .iter()
        .map(|&t| (t * t) as f64 / 2.0 / (c_ref * c_ref) * cost.pair_full_s)
        .sum();
    assert!(
        (busy - want).abs() <= 1e-9 * want,
        "busy {busy} vs doc-exact {want}"
    );
}

#[test]
fn equal_chunk_degenerate_bit_matches_classic_lowering() {
    // one document spanning everything, equal chunks: every token scale
    // collapses to the reference, so the varlen lowering must emit the
    // *identical* op stream (and therefore bit-identical timings)
    let cluster = ClusterSpec::dgx_2x8();
    let cost = test_cost();
    for p in [2usize, 5, 8, 16] {
        let spec = VarlenSpec::uniform(128, p);
        let lopts = LowerOpts { varlen: Some(Arc::new(spec)), ..Default::default() };
        let s = Schedule::balanced(p);
        for pass in [Pass::Forward, Pass::Backward] {
            let classic = Plan::from_schedule(&s, pass);
            let varlen = Plan::from_schedule_opts(&s, pass, &lopts);
            assert_eq!(classic.ops, varlen.ops, "P={p} {pass:?}: op streams differ");
            for depth in [0usize, 1, 4] {
                let a = PlanSim::new(&classic, &cost).total_s(&cluster, &classic.placement, depth);
                let b = PlanSim::new(&varlen, &cost).total_s(&cluster, &varlen.placement, depth);
                assert_eq!(a.to_bits(), b.to_bits(), "P={p} {pass:?} depth {depth}");
            }
        }
    }
}

#[test]
fn incremental_rescore_matches_full_resimulate() {
    // arbitrary move sequences (random cost patches, including zeroing)
    // replayed incrementally must agree bit-for-bit with a from-scratch
    // pass over the same cost state
    let cluster = ClusterSpec::dgx_2x8();
    let cost = test_cost();
    let p = 16usize;
    let spec = VarlenSpec::pack_zipf(32, 512 * p, 1.3, 3, p);
    let lopts = LowerOpts {
        varlen: Some(Arc::new(spec)),
        dense_duals: true,
        ..Default::default()
    };
    let place: Vec<usize> = (0..p).collect();
    for pass in [Pass::Forward, Pass::Backward] {
        let plan = Plan::from_schedule_opts(&Schedule::balanced(p), pass, &lopts);
        let mut inc = PlanSim::new(&plan, &cost);
        let mut full = PlanSim::new(&plan, &cost);
        assert_eq!(
            inc.rescore(&cluster, &place, 1).to_bits(),
            full.total_s(&cluster, &place, 1).to_bits()
        );
        let mut rng = Rng::new(9);
        for iter in 0..60 {
            for _ in 0..1 + rng.below(8) {
                let i = rng.below(plan.n_ops());
                let v = inc.op_cost(i);
                let nv = match rng.below(3) {
                    0 => 0.0,
                    1 => v * 0.5 + 1e-7,
                    _ => v + 1e-4,
                };
                inc.set_op_cost(i, nv);
                full.set_op_cost(i, nv);
            }
            let a = inc.rescore(&cluster, &place, 1);
            let b = full.total_s(&cluster, &place, 1);
            assert_eq!(a.to_bits(), b.to_bits(), "{pass:?} iter {iter}");
        }
        // a depth/placement change must fall back to a full pass
        let mut perm = place.clone();
        perm.swap(0, p - 1);
        let a = inc.rescore(&cluster, &perm, 2);
        let b = full.total_s(&cluster, &perm, 2);
        assert_eq!(a.to_bits(), b.to_bits(), "{pass:?} after reconfig");
    }
}

#[test]
fn per_pair_flip_bitmap_preserves_invariants() {
    // flipping a scattered subset of helper pairs (the per-pair bitmap,
    // finer than PR 2's per-step flips) must keep the lowering valid with
    // the exact same pair coverage
    let p = 12usize;
    let s = Schedule::balanced(p);
    let mut lopts = LowerOpts::default();
    let mut flipped = 0usize;
    for (t, row) in s.steps.iter().enumerate() {
        for (w, sp) in row.iter().enumerate() {
            if let Some(ComputeOp::Help { .. }) = sp.compute {
                if (t + w) % 2 == 0 {
                    lopts.set_flip_pair(t, w, p, true);
                    flipped += 1;
                }
            }
        }
    }
    assert!(flipped > 0, "schedule must have helper pairs to flip");
    assert_eq!(lopts.flipped_pair_count(), flipped);
    for pass in [Pass::Forward, Pass::Backward] {
        let base = Plan::from_schedule(&s, pass);
        let plan = Plan::from_schedule_opts(&s, pass, &lopts);
        plan.validate_lowered().unwrap_or_else(|e| panic!("{pass:?}: {e}"));
        assert_eq!(pair_set(&base), pair_set(&plan), "{pass:?}");
    }
}

#[test]
fn rebalancer_clears_acceptance_bar_on_zipf_2x8() {
    // the ISSUE's acceptance criterion: skewed Zipf packing on the 2x8
    // cluster, >= 1.2x simulated end-to-end over pad-to-max, search
    // within PR 2's sim-call budget order, never worse than equal-token
    let cluster = ClusterSpec::dgx_2x8();
    let model = PaperModel::llama_7b();
    let p = cluster.n_gpus();
    let seq = 2048usize;
    let spec = VarlenSpec::pack_zipf(64, seq * p, 1.1, 17, p);
    let s = Schedule::balanced(p);
    for (pass, cost) in [
        (Pass::Forward, attn_cost_fwd(&model, &cluster, seq as f64)),
        (Pass::Backward, attn_cost_bwd(&model, &cluster, seq as f64)),
    ] {
        let o = optimize_varlen(&s, &spec, pass, &cluster, &cost, &OptimizeOpts::default());
        o.plan.validate_lowered().unwrap_or_else(|e| panic!("{pass:?}: {e}"));
        assert!(
            o.optimized_s <= o.equal_s * (1.0 + 1e-9),
            "{pass:?}: rebalancer pessimized {} -> {}",
            o.equal_s,
            o.optimized_s
        );
        assert!(
            o.speedup_vs_pad() >= 1.2,
            "{pass:?}: only {:.2}x over pad-to-max",
            o.speedup_vs_pad()
        );
        assert!(o.sim_calls < 2500, "{pass:?}: {} sim calls", o.sim_calls);
        assert!(
            o.incremental_rescores > 0,
            "{pass:?}: incremental rescoring never fired"
        );
        // the final plan covers exactly the positive-weight pairs of the
        // final boundaries
        let pairs = pair_set(&o.plan);
        for q in 0..p {
            for kv in 0..=q {
                assert_eq!(
                    pairs.contains(&(q, kv)),
                    o.spec.pair_weight(q, kv) > 0.0,
                    "{pass:?}: pair ({q},{kv})"
                );
            }
        }
    }
}

#[test]
fn doc_aligned_cuts_converge_in_fewer_sims_on_doc_heavy_mixes() {
    // ISSUE satellite: when documents are comparable in size to chunks,
    // the pair-weight function is kinked at the (few) document edges —
    // snapping candidate cuts to those kinks should reach convergence in
    // fewer-or-equal simulator calls than blindly walking the c_ref/16
    // grid, summed over several packings so one lucky seed can't decide.
    let cluster = ClusterSpec::dgx_2x8();
    let cost = test_cost();
    let p = 8usize;
    let s = Schedule::balanced(p);
    let mut aligned_sims = 0usize;
    let mut grid_sims = 0usize;
    for seed in [3u64, 5, 9] {
        let spec = VarlenSpec::pack_zipf(6, 192 * p, 1.3, seed, p);
        let aligned =
            optimize_varlen(&s, &spec, Pass::Forward, &cluster, &cost, &OptimizeOpts::default());
        let grid = optimize_varlen(
            &s,
            &spec,
            Pass::Forward,
            &cluster,
            &cost,
            &OptimizeOpts { align_doc_cuts: false, ..Default::default() },
        );
        aligned_sims += aligned.sim_calls;
        grid_sims += grid.sim_calls;
        // alignment is a search-policy change only: the never-worse
        // contract vs the equal-token default must still hold
        assert!(aligned.optimized_s <= aligned.equal_s * (1.0 + 1e-9), "seed {seed}");
        aligned.plan.validate_lowered().unwrap();
    }
    assert!(
        aligned_sims <= grid_sims,
        "doc-aligned candidates should not need more sims: {aligned_sims} vs {grid_sims}"
    );
}

#[test]
fn move_boundaries_off_fixes_cuts_but_still_flips() {
    // the Session's shared-chunking backward pass relies on this knob:
    // boundary sweeps disabled, flip sweeps (and placement/depth) intact
    let cluster = ClusterSpec::dgx_2x8();
    let p = cluster.n_gpus();
    let spec = VarlenSpec::pack_zipf(48, 512 * p, 1.2, 5, p);
    let o = optimize_varlen(
        &Schedule::balanced(p),
        &spec,
        Pass::Backward,
        &cluster,
        &test_cost(),
        &OptimizeOpts { move_boundaries: false, ..Default::default() },
    );
    assert_eq!(o.moved_boundaries, 0, "cuts moved despite move_boundaries=false");
    assert_eq!(o.spec.boundaries, spec.boundaries);
    assert!(o.optimized_s <= o.equal_s * (1.0 + 1e-9));
    o.plan.validate_lowered().unwrap();
}

#[test]
fn varlen_harness_plans_build_and_shard_raggedly() {
    let spec = VarlenSpec::pack_zipf(10, 96, 1.0, 1, 4);
    let mut rs = RunSpec::plans_only(ScheduleKind::Balanced, 4);
    rs.varlen = Some(spec.clone());
    let (fwd, bwd) = Session::new(rs).unwrap().plans().unwrap();
    assert_eq!(fwd.n_workers, 4);
    assert!(fwd.varlen.is_some() && bwd.varlen.is_some());
    // ragged shard/gather round-trip at the spec's boundaries
    let t = Tensor::new(vec![2, 96, 3], (0..2 * 96 * 3).map(|x| x as f32).collect());
    let parts = t.chunk_axis1_at(&spec.boundaries);
    assert_eq!(parts.len(), 4);
    for (i, part) in parts.iter().enumerate() {
        assert_eq!(part.shape, vec![2, spec.chunk_tokens(i), 3]);
    }
    let back = Tensor::cat_axis1(&parts);
    assert_eq!(back.shape, t.shape);
    assert_eq!(back, t);
}
