//! Tiled-kernel equivalence and determinism properties.
//!
//! The tiled/vectorized host kernels (`HostKernels::tiled`) replace the
//! scalar originals as the executor's default, so two properties carry
//! every numeric pin in the repo:
//!
//! * **agreement with the oracle** — tiled output matches the scalar
//!   kernels within floating-point reassociation tolerance, across GQA
//!   group sizes, ragged (cq != ck) chunk pairs, causal and full pairs,
//!   nonzero initial accumulators, and adversarial sizes straddling the
//!   tile boundaries (1, 7, 17, 31, 33, 63, 65, ...);
//! * **bit-identity across thread counts** — the (head, q-tile) work
//!   decomposition keeps every output row's reduction inside one unit in
//!   a fixed order, so `tiled(n)` is *exactly* `tiled(1)` for every n,
//!   which is what lets `RunSpec::threads` trade wall-clock without
//!   perturbing traces or golden values.

use distflash::coordinator::{RunSpec, ScheduleKind, Session, Workload};
use distflash::runtime::{HostKernels, Kernels, Tensor, Value};
use distflash::util::Rng;

fn rand3(rng: &mut Rng, shape: [usize; 3]) -> Tensor {
    Tensor::new(shape.to_vec(), rng.normal_vec(shape.iter().product()))
}

fn vals(ts: &[&Tensor]) -> Vec<Value> {
    ts.iter().map(|t| Value::F32((*t).clone())).collect()
}

/// Assert `got` matches `want` within reassociation tolerance, scaled by
/// the oracle's own magnitude.
fn assert_close(what: &str, got: &Tensor, want: &Tensor, tol: f32) {
    assert_eq!(got.shape, want.shape, "{what}: shape mismatch");
    let scale = want.data().iter().fold(0.0f32, |a, x| a.max(x.abs()));
    let diff = got.max_abs_diff(want);
    assert!(
        diff <= tol * (1.0 + scale),
        "{what}: max |Δ| = {diff:e} exceeds {tol:e} * (1 + {scale:e})"
    );
}

/// Assert `got` is bit-identical to `want` (thread-count determinism).
fn assert_identical(what: &str, got: &Tensor, want: &Tensor) {
    assert_eq!(got.shape, want.shape, "{what}: shape mismatch");
    assert_eq!(got.max_abs_diff(want), 0.0, "{what}: not bit-identical");
}

/// (h, kvh, cq, ck, d) grid: MHA and GQA groupings, ragged pairs, and
/// sizes placed on and around the TILE_Q=32 / TILE_K=64 / LANES=8 edges.
const SHAPES: &[(usize, usize, usize, usize, usize)] = &[
    (1, 1, 1, 1, 1),
    (2, 1, 7, 5, 3),
    (4, 2, 17, 17, 8),
    (3, 3, 33, 31, 5),
    (8, 2, 33, 33, 64),
    (6, 3, 65, 65, 33),
    (4, 1, 64, 128, 16),
    (2, 2, 63, 63, 128),
];

/// Fresh (q, k, v, o0, m0, l0) forward inputs for one grid point.
fn fwd_inputs(
    rng: &mut Rng,
    h: usize,
    kvh: usize,
    cq: usize,
    ck: usize,
    d: usize,
) -> Vec<Value> {
    let q = rand3(rng, [h, cq, d]);
    let k = rand3(rng, [kvh, ck, d]);
    let v = rand3(rng, [kvh, ck, d]);
    let o0 = Tensor::zeros(&[h, cq, d]);
    let m0 = Tensor::full(&[h, cq], f32::NEG_INFINITY);
    let l0 = Tensor::zeros(&[h, cq]);
    vals(&[&q, &k, &v, &o0, &m0, &l0])
}

#[test]
fn chunk_fwd_matches_scalar_across_shapes() {
    let mut rng = Rng::new(1);
    for &(h, kvh, cq, ck, d) in SHAPES {
        for name in ["attn_fwd_full", "attn_fwd_diag"] {
            if name == "attn_fwd_diag" && cq != ck {
                continue;
            }
            let inputs = fwd_inputs(&mut rng, h, kvh, cq, ck, d);
            let want = HostKernels::scalar().run(name, &inputs).unwrap();
            let got = HostKernels::tiled(1).run(name, &inputs).unwrap();
            let what = format!("{name} h{h}/kvh{kvh} {cq}x{ck} d{d}");
            for (g, w) in got.iter().zip(&want) {
                assert_close(&what, g, w, 1e-4);
            }
        }
    }
}

#[test]
fn chunk_fwd_matches_scalar_from_nonzero_accumulators() {
    // chain two kv chunks: the second fold starts from a live (o, m, l)
    // state, exercising the alpha-rescale path in both implementations
    let mut rng = Rng::new(2);
    for &(h, kvh, cq, _, d) in &[(4usize, 2usize, 33usize, 0usize, 24usize), (3, 1, 17, 0, 7)] {
        let q = rand3(&mut rng, [h, cq, d]);
        let k1 = rand3(&mut rng, [kvh, 19, d]);
        let v1 = rand3(&mut rng, [kvh, 19, d]);
        let k2 = rand3(&mut rng, [kvh, 65, d]);
        let v2 = rand3(&mut rng, [kvh, 65, d]);
        let o0 = Tensor::zeros(&[h, cq, d]);
        let m0 = Tensor::full(&[h, cq], f32::NEG_INFINITY);
        let l0 = Tensor::zeros(&[h, cq]);
        let run2 = |kk: &HostKernels| -> Vec<Tensor> {
            let s1 = kk
                .run("attn_fwd_full", &vals(&[&q, &k1, &v1, &o0, &m0, &l0]))
                .unwrap();
            kk.run("attn_fwd_full", &vals(&[&q, &k2, &v2, &s1[0], &s1[1], &s1[2]]))
                .unwrap()
        };
        let want = run2(&HostKernels::scalar());
        let got = run2(&HostKernels::tiled(1));
        for (g, w) in got.iter().zip(&want) {
            assert_close(&format!("chained fwd h{h} cq{cq} d{d}"), g, w, 1e-4);
        }
    }
}

#[test]
fn chunk_bwd_matches_scalar_across_shapes() {
    let mut rng = Rng::new(3);
    for &(h, kvh, cq, ck, d) in SHAPES {
        for name in ["attn_bwd_full", "attn_bwd_diag"] {
            if name == "attn_bwd_diag" && cq != ck {
                continue;
            }
            let q = rand3(&mut rng, [h, cq, d]);
            let k = rand3(&mut rng, [kvh, ck, d]);
            let v = rand3(&mut rng, [kvh, ck, d]);
            let do_ = rand3(&mut rng, [h, cq, d]);
            // a consistent (o, lse) pair from a real forward over the pair
            let causal = name == "attn_bwd_diag";
            let fwd_name = if causal { "attn_fwd_diag" } else { "attn_fwd_full" };
            let o0 = Tensor::zeros(&[h, cq, d]);
            let m0 = Tensor::full(&[h, cq], f32::NEG_INFINITY);
            let l0 = Tensor::zeros(&[h, cq]);
            let oml = HostKernels::scalar()
                .run(fwd_name, &vals(&[&q, &k, &v, &o0, &m0, &l0]))
                .unwrap();
            let fin = HostKernels::scalar()
                .run("attn_finalize", &vals(&[&oml[0], &oml[1], &oml[2]]))
                .unwrap();
            let inputs = vals(&[&q, &k, &v, &fin[0], &fin[1], &do_]);
            let want = HostKernels::scalar().run(name, &inputs).unwrap();
            let got = HostKernels::tiled(1).run(name, &inputs).unwrap();
            let what = format!("{name} h{h}/kvh{kvh} {cq}x{ck} d{d}");
            for (g, w) in got.iter().zip(&want) {
                assert_close(&what, g, w, 2e-4);
            }
        }
    }
}

#[test]
fn rescale_and_finalize_match_scalar() {
    let mut rng = Rng::new(4);
    for &(h, kvh, c, _, d) in &[(4usize, 2usize, 33usize, 0usize, 40usize), (2, 1, 9, 0, 3)] {
        let q = rand3(&mut rng, [h, c, d]);
        let o0 = Tensor::zeros(&[h, c, d]);
        let m0 = Tensor::full(&[h, c], f32::NEG_INFINITY);
        let l0 = Tensor::zeros(&[h, c]);
        // two partial states over different kv chunks, both from the oracle
        // so the rescale/finalize inputs are identical across arms
        let part = |rng: &mut Rng, ck: usize| -> Vec<Tensor> {
            let k = rand3(rng, [kvh, ck, d]);
            let v = rand3(rng, [kvh, ck, d]);
            HostKernels::scalar()
                .run("attn_fwd_full", &vals(&[&q, &k, &v, &o0, &m0, &l0]))
                .unwrap()
        };
        let s1 = part(&mut rng, 21);
        let s2 = part(&mut rng, 64);
        let rin = vals(&[&s1[0], &s1[1], &s1[2], &s2[0], &s2[1], &s2[2]]);
        let want = HostKernels::scalar().run("attn_rescale", &rin).unwrap();
        let got = HostKernels::tiled(1).run("attn_rescale", &rin).unwrap();
        for (g, w) in got.iter().zip(&want) {
            assert_close(&format!("rescale h{h} c{c} d{d}"), g, w, 1e-4);
        }
        let fin = vals(&[&want[0], &want[1], &want[2]]);
        let want_f = HostKernels::scalar().run("attn_finalize", &fin).unwrap();
        let got_f = HostKernels::tiled(1).run("attn_finalize", &fin).unwrap();
        for (g, w) in got_f.iter().zip(&want_f) {
            assert_close(&format!("finalize h{h} c{c} d{d}"), g, w, 1e-4);
        }
    }
}

#[test]
fn finalize_rejects_empty_rows_in_both_modes() {
    let o = Tensor::zeros(&[1, 2, 4]);
    let m = Tensor::full(&[1, 2], f32::NEG_INFINITY);
    let l = Tensor::zeros(&[1, 2]);
    let inputs = vals(&[&o, &m, &l]);
    assert!(HostKernels::scalar().run("attn_finalize", &inputs).is_err());
    assert!(HostKernels::tiled(1).run("attn_finalize", &inputs).is_err());
    assert!(HostKernels::tiled(4).run("attn_finalize", &inputs).is_err());
}

#[test]
fn full_attn_ref_matches_scalar() {
    let mut rng = Rng::new(5);
    for &(h, kvh, n, d) in &[(4usize, 2usize, 65usize, 32usize), (2, 1, 33, 128), (1, 1, 1, 1)] {
        let q = rand3(&mut rng, [h, n, d]);
        let k = rand3(&mut rng, [kvh, n, d]);
        let v = rand3(&mut rng, [kvh, n, d]);
        let inputs = vals(&[&q, &k, &v]);
        let want = HostKernels::scalar().run("full_attn_ref", &inputs).unwrap();
        let got = HostKernels::tiled(1).run("full_attn_ref", &inputs).unwrap();
        for (g, w) in got.iter().zip(&want) {
            assert_close(&format!("full_attn_ref h{h} n{n} d{d}"), g, w, 1e-4);
        }
    }
}

#[test]
fn every_kernel_is_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(6);
    let (h, kvh, cq, ck, d) = (6, 3, 65, 65, 33);
    let q = rand3(&mut rng, [h, cq, d]);
    let k = rand3(&mut rng, [kvh, ck, d]);
    let v = rand3(&mut rng, [kvh, ck, d]);
    let do_ = rand3(&mut rng, [h, cq, d]);
    let o0 = Tensor::zeros(&[h, cq, d]);
    let m0 = Tensor::full(&[h, cq], f32::NEG_INFINITY);
    let l0 = Tensor::zeros(&[h, cq]);
    let fwd = vals(&[&q, &k, &v, &o0, &m0, &l0]);
    let oml = HostKernels::tiled(1).run("attn_fwd_diag", &fwd).unwrap();
    let fin_in = vals(&[&oml[0], &oml[1], &oml[2]]);
    let fin = HostKernels::tiled(1).run("attn_finalize", &fin_in).unwrap();
    let bwd = vals(&[&q, &k, &v, &fin[0], &fin[1], &do_]);
    let resc = vals(&[&oml[0], &oml[1], &oml[2], &oml[0], &oml[1], &oml[2]]);
    let full = vals(&[&q, &k, &v]);
    for (name, inputs) in [
        ("attn_fwd_full", &fwd),
        ("attn_fwd_diag", &fwd),
        ("attn_rescale", &resc),
        ("attn_finalize", &fin_in),
        ("attn_bwd_full", &bwd),
        ("attn_bwd_diag", &bwd),
        ("full_attn_ref", &full),
    ] {
        let base = HostKernels::tiled(1).run(name, inputs).unwrap();
        for threads in [2usize, 3, 8] {
            let got = HostKernels::tiled(threads).run(name, inputs).unwrap();
            assert_eq!(base.len(), got.len(), "{name}: output arity");
            for (g, w) in got.iter().zip(&base) {
                assert_identical(&format!("{name} @ {threads} threads"), g, w);
            }
        }
    }
}

#[test]
fn spec_rejects_zero_threads() {
    let mut spec = RunSpec::host(ScheduleKind::Balanced, 2, Workload::new(2, 2, 8, 16));
    spec.threads = 0;
    let err = Session::new(spec).err().expect("threads=0 must be rejected");
    assert!(err.to_string().contains("threads"), "unexpected error: {err}");
}

#[test]
fn executed_run_is_bit_identical_across_thread_counts_and_records_them() {
    let run_with = |threads: usize| {
        let mut spec = RunSpec::host(ScheduleKind::Balanced, 2, Workload::new(4, 2, 16, 24));
        spec.trace = true;
        spec.threads = threads;
        spec.seed = 9;
        let mut s = Session::new(spec).unwrap();
        s.execute().unwrap();
        let recorded = s.trace().unwrap().fwd.threads;
        let r = s.take_run().unwrap();
        (r.result, recorded)
    };
    let (base, rec1) = run_with(1);
    assert_eq!(rec1, 1, "threads=1 must be recorded as-is");
    let (multi, rec3) = run_with(3);
    assert!(
        (1..=3).contains(&rec3),
        "effective threads must be clamped to 1..=requested, got {rec3}"
    );
    assert_eq!(base.o.max_abs_diff(&multi.o), 0.0, "o must be bit-identical");
    assert_eq!(base.lse.max_abs_diff(&multi.lse), 0.0, "lse must be bit-identical");
    let (gb, gm) = (base.grads.as_ref().unwrap(), multi.grads.as_ref().unwrap());
    assert_eq!(gb.0.max_abs_diff(&gm.0), 0.0, "dq must be bit-identical");
    assert_eq!(gb.1.max_abs_diff(&gm.1), 0.0, "dk must be bit-identical");
    assert_eq!(gb.2.max_abs_diff(&gm.2), 0.0, "dv must be bit-identical");
}
