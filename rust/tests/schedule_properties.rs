//! Property suite pinning the paper's schedule invariants across
//! `P = 1..=64`, both `ScheduleKind`s, and both passes — at the
//! `Schedule` level and through the IR lowering. (proptest is unavailable
//! offline; an exhaustive sweep over every P in range is strictly
//! stronger than sampling anyway.)

use std::collections::HashSet;

use distflash::coordinator::schedule::{balanced_idle_fraction_eq2, ring_idle_fraction};
use distflash::coordinator::{
    ComputeOp, Pass, Payload, PlanOp, Schedule, ScheduleKind, StepPlan,
};

const KINDS: [ScheduleKind; 2] = [ScheduleKind::Ring, ScheduleKind::Balanced];
const PASSES: [Pass; 2] = [Pass::Forward, Pass::Backward];

#[test]
fn every_causal_pair_exactly_once_all_p() {
    for p in 1..=64 {
        for kind in KINDS {
            let s = Schedule::build(kind, p);
            s.validate().unwrap_or_else(|e| panic!("{kind:?} P={p}: {e}"));
            let mut seen = HashSet::new();
            for ((o, kv), _) in s.computed_pairs() {
                assert!(kv <= o, "{kind:?} P={p}: non-causal ({o},{kv})");
                assert!(seen.insert((o, kv)), "{kind:?} P={p}: dup ({o},{kv})");
            }
            assert_eq!(seen.len(), p * (p + 1) / 2, "{kind:?} P={p}");
            // the lowered IR must compute the identical pair set, both
            // passes
            for pass in PASSES {
                let plan = s.lower(pass);
                plan.validate_lowered()
                    .unwrap_or_else(|e| panic!("{kind:?} P={p} {pass:?}: {e}"));
                let ir: HashSet<(usize, usize)> =
                    plan.computed_pairs().into_iter().map(|(pr, _)| pr).collect();
                assert_eq!(ir, seen, "{kind:?} P={p} {pass:?}");
            }
        }
    }
}

#[test]
fn idle_fraction_matches_closed_forms_all_p() {
    for p in 1..=64 {
        // ring: (P^2 - P) / 2P^2 over its own P x P timeline
        let ring = Schedule::ring(p);
        assert!(
            (ring.idle_fraction() - ring_idle_fraction(p)).abs() < 1e-12,
            "P={p}: {} vs {}",
            ring.idle_fraction(),
            ring_idle_fraction(p)
        );
        // paper Eq. (2): balanced idle slots normalized by the ring's P^2
        // timeline -> 1/2P (P even), 0 (P odd)
        let bal = Schedule::balanced(p);
        let got = bal.idle_slots() as f64 / (p * p) as f64;
        assert!(
            (got - balanced_idle_fraction_eq2(p)).abs() < 1e-12,
            "P={p}: {got} vs {}",
            balanced_idle_fraction_eq2(p)
        );
    }
}

#[test]
fn balanced_timeline_and_speedup_dominate_all_p() {
    for p in 2..=64 {
        let bal = Schedule::balanced(p);
        assert_eq!(bal.n_steps(), p / 2 + 1, "P={p}");
        assert!(
            bal.ideal_speedup() >= Schedule::ring(p).ideal_speedup(),
            "P={p}"
        );
    }
}

#[test]
fn validate_accepts_generated_and_rejects_mutated() {
    for p in 2..=16 {
        for kind in KINDS {
            let good = Schedule::build(kind, p);
            good.validate().unwrap();

            // (a) drop a kv send -> the matching Own compute dangles
            let mut s = good.clone();
            let mut mutated = false;
            'outer: for row in &mut s.steps {
                for plan in row.iter_mut() {
                    if plan.send_kv_to.is_some() {
                        plan.send_kv_to = None;
                        mutated = true;
                        break 'outer;
                    }
                }
            }
            if mutated {
                assert!(s.validate().is_err(), "{kind:?} P={p}: dropped send accepted");
            }

            // (b) append a step recomputing the (0, 0) diagonal -> dup pair
            let mut s = good.clone();
            let mut row = vec![StepPlan::default(); p];
            row[0].compute = Some(ComputeOp::Diag);
            s.steps.push(row);
            assert!(s.validate().is_err(), "{kind:?} P={p}: dup pair accepted");
        }
    }
}

#[test]
fn lowered_plan_rejects_mutations() {
    for p in 2..=16 {
        for kind in KINDS {
            for pass in PASSES {
                let mut plan = Schedule::build(kind, p).lower(pass);
                // retarget a kv transfer: breaks the stream-owner and
                // fetch-wiring invariants
                let idx = plan
                    .ops
                    .iter()
                    .position(|n| matches!(n.op, PlanOp::Xfer { payload: Payload::Kv, .. }))
                    .expect("every P >= 2 schedule ships kv");
                if let PlanOp::Xfer { dst, .. } = &mut plan.ops[idx].op {
                    *dst = (*dst + 1) % p;
                }
                assert!(
                    plan.validate_lowered().is_err(),
                    "{kind:?} P={p} {pass:?}: retargeted transfer accepted"
                );
            }
        }
    }
}

#[test]
fn wire_tags_unique_within_every_plan_all_p() {
    for p in 1..=64 {
        for kind in KINDS {
            for pass in PASSES {
                let plan = Schedule::build(kind, p).lower(pass);
                let tags = plan.wire_tags(7);
                let set: HashSet<_> = tags.iter().cloned().collect();
                assert_eq!(set.len(), tags.len(), "{kind:?} P={p} {pass:?}");
            }
        }
    }
}
