//! Comm-layer regression: the executor's wire protocol against the plan
//! IR, without PJRT. A dry-run executor walks the lowered plans over the
//! real channel fabric with correctly-shaped dummy tensors — with
//! collective traffic interleaved on the same fabric — proving (1) tag
//! uniqueness across rounds and semantic spaces (no cross-talk), and
//! (2) `bytes_sent_global()` exactly matching the byte count the
//! simulator predicts for the same plans via `Plan::total_bytes`.

use std::collections::HashSet;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use distflash::coordinator::comm::{build_network, build_network_placed, Tag, WorkerComm};
use distflash::coordinator::{
    BackendSpec, CommError, FaultEvent, FaultSpec, Kernel, Pass, Payload, PayloadClass, Plan,
    PlanOp, RankFaults, RunSpec, Schedule, ScheduleKind, Session,
};
use distflash::runtime::Tensor;
use distflash::simulator::AttnCost;

// GQA shapes (kv heads != q heads) to catch payload-size mixups
const H: usize = 4;
const KVH: usize = 2;
const C: usize = 8;
const D: usize = 16;

fn f32s(n: usize) -> usize {
    n * 4
}

/// Per-payload tensor shapes exactly as the executor ships them (keyed by
/// payload *class* — token-scaled variants ship the same tensor kinds).
fn payload_tensors(payload: &Payload, pass: Pass) -> Vec<Tensor> {
    match (payload.class(), pass) {
        (PayloadClass::Kv, _) => vec![Tensor::zeros(&[KVH, C, D]), Tensor::zeros(&[KVH, C, D])],
        (PayloadClass::QBundle, Pass::Forward) => vec![Tensor::zeros(&[H, C, D])],
        (PayloadClass::QBundle, Pass::Backward) => vec![
            Tensor::zeros(&[H, C, D]),
            Tensor::zeros(&[H, C, D]),
            Tensor::zeros(&[H, C]),
            Tensor::zeros(&[H, C, D]),
        ],
        (PayloadClass::HelperResult, Pass::Forward) => vec![
            Tensor::zeros(&[H, C, D]),
            Tensor::zeros(&[H, C]),
            Tensor::zeros(&[H, C]),
        ],
        (PayloadClass::HelperResult, Pass::Backward) => vec![Tensor::zeros(&[H, C, D])],
        (PayloadClass::KvGrad, _) => {
            vec![Tensor::zeros(&[KVH, C, D]), Tensor::zeros(&[KVH, C, D])]
        }
        (PayloadClass::Raw, _) => vec![],
    }
}

/// Byte-accurate cost model for those shapes (f32 host wire), so the
/// simulator-side `Plan::total_bytes` predicts the executor's counters.
fn wire_cost(pass: Pass) -> AttnCost {
    let (q_bytes, result_bytes) = match pass {
        Pass::Forward => (f32s(H * C * D) as f64, f32s(H * C * D + 2 * H * C) as f64),
        Pass::Backward => (f32s(3 * H * C * D + H * C) as f64, f32s(H * C * D) as f64),
    };
    AttnCost {
        pair_full_s: 0.0,
        pair_diag_s: 0.0,
        rescale_s: 0.0,
        kv_bytes: f32s(2 * KVH * C * D) as f64,
        q_bytes,
        result_bytes,
        overlap: true,
    }
}

/// Walk a plan the way the executor does, minus the kernels: eager sends
/// where this rank is the source, blocking receives where its computes
/// consume inbound data.
fn dry_run(plan: &Plan, rank: usize, comm: &mut WorkerComm, call_id: u32) {
    let tag = |space: u32, step: usize| Tag::new(space, call_id, step as u32);
    for node in &plan.ops {
        match &node.op {
            PlanOp::Xfer { src, dst, payload } if *src == rank => {
                comm.send(
                    *dst,
                    tag(payload.tag_space(), node.step),
                    payload_tensors(payload, plan.pass),
                )
                .unwrap();
            }
            PlanOp::Compute { kernel, pair } if node.worker == rank => match kernel {
                Kernel::AttnFull => {
                    let (owner, kv_chunk) = pair.unwrap();
                    if owner == rank {
                        let got = comm.recv(kv_chunk, tag(Tag::KV, node.step)).unwrap();
                        assert_eq!(got.len(), 2);
                        assert_eq!(got[0].shape, vec![KVH, C, D]);
                    } else {
                        let want = if plan.pass == Pass::Forward { 1 } else { 4 };
                        let got = comm.recv(owner, tag(Tag::Q_BUNDLE, node.step)).unwrap();
                        assert_eq!(got.len(), want, "bundle size for {:?}", plan.pass);
                    }
                }
                Kernel::Rescale => {
                    let from = node
                        .deps
                        .iter()
                        .find_map(|&d| match &plan.ops[d].op {
                            PlanOp::Xfer { src, payload: Payload::HelperResult, .. } => Some(*src),
                            _ => None,
                        })
                        .expect("rescale has a helper-result dep");
                    comm.recv(from, tag(Tag::HELPER_RESULT, node.step)).unwrap();
                }
                Kernel::Accum => {
                    for &d in &node.deps {
                        if let PlanOp::Xfer { src, payload: Payload::KvGrad, .. } = &plan.ops[d].op
                        {
                            let got =
                                comm.recv(*src, tag(Tag::KV_GRAD, plan.ops[d].step)).unwrap();
                            assert_eq!(got[0].shape, vec![KVH, C, D]);
                        }
                    }
                }
                _ => {}
            },
            _ => {}
        }
    }
}

#[test]
fn executor_bytes_match_plan_prediction_with_collectives_interleaved() {
    for kind in [ScheduleKind::Ring, ScheduleKind::Balanced] {
        let p = 4usize;
        let s = Schedule::build(kind, p);
        let fwd = Arc::new(s.lower(Pass::Forward));
        let bwd = Arc::new(s.lower(Pass::Backward));
        let comms = build_network(p);
        let handles: Vec<_> = comms
            .into_iter()
            .enumerate()
            .map(|(rank, mut comm)| {
                let fwd = fwd.clone();
                let bwd = bwd.clone();
                thread::spawn(move || {
                    dry_run(&fwd, rank, &mut comm, 0);
                    // collective traffic on the same fabric, between the
                    // two attention calls: results must be exact (no
                    // cross-talk with schedule messages)
                    let mut t = Tensor::full(&[12], (rank + 1) as f32);
                    comm.all_reduce_sum(1000, &mut t).unwrap();
                    assert!(t.data().iter().all(|&x| x == 10.0), "all-reduce corrupted");
                    let all = comm.all_gather(2000, &Tensor::scalar(rank as f32)).unwrap();
                    for (i, g) in all.iter().enumerate() {
                        assert_eq!(g.as_scalar(), i as f32, "all-gather corrupted");
                    }
                    dry_run(&bwd, rank, &mut comm, 1);
                    comm.barrier(3000).unwrap();
                    comm.bytes_sent_global()
                })
            })
            .collect();
        let totals: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for t in &totals {
            assert_eq!(*t, totals[0], "{kind:?}: global counter disagrees");
        }
        // simulator-predicted attention bytes + exact collective bytes
        let plan_bytes =
            fwd.total_bytes(&wire_cost(Pass::Forward)) + bwd.total_bytes(&wire_cost(Pass::Backward));
        let all_reduce = (p * 2 * (p - 1) * 3 * 4) as u64; // 2(P-1) segments of 3 f32 each
        let all_gather = (p * (p - 1) * 4) as u64; // one scalar to each peer
        let barrier = (p * (p - 1) * 4) as u64;
        assert_eq!(
            totals[0],
            plan_bytes as u64 + all_reduce + all_gather + barrier,
            "{kind:?}: executor bytes diverge from plan prediction"
        );
    }
}

#[test]
fn placed_network_bytes_match_plan_prediction() {
    // rank i's mailbox bound to slot placement[i] (the launcher consuming
    // `Plan::placement`): the wire protocol is placement-agnostic, so the
    // dry-run executor must complete and its byte counters must still
    // match the plan's prediction exactly
    let p = 4usize;
    let placement: Vec<usize> = (0..p).map(|i| (i + 3) % p).collect();
    let s = Schedule::build(ScheduleKind::Balanced, p);
    let mut fwd_plan = s.lower(Pass::Forward);
    let mut bwd_plan = s.lower(Pass::Backward);
    fwd_plan.placement = placement.clone();
    bwd_plan.placement = placement.clone();
    fwd_plan.validate_lowered().unwrap();
    bwd_plan.validate_lowered().unwrap();
    let fwd = Arc::new(fwd_plan);
    let bwd = Arc::new(bwd_plan);
    let comms = build_network_placed(p, &placement);
    let handles: Vec<_> = comms
        .into_iter()
        .enumerate()
        .map(|(rank, mut comm)| {
            let fwd = fwd.clone();
            let bwd = bwd.clone();
            thread::spawn(move || {
                dry_run(&fwd, rank, &mut comm, 0);
                dry_run(&bwd, rank, &mut comm, 1);
                comm.barrier(3000).unwrap();
                comm.bytes_sent_global()
            })
        })
        .collect();
    let totals: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let plan_bytes =
        fwd.total_bytes(&wire_cost(Pass::Forward)) + bwd.total_bytes(&wire_cost(Pass::Backward));
    let barrier = (p * (p - 1) * 4) as u64;
    for t in &totals {
        assert_eq!(
            *t,
            plan_bytes as u64 + barrier,
            "placed fabric diverges from plan-predicted bytes"
        );
    }
}

#[test]
fn real_executor_traced_bytes_match_plan_prediction() {
    // the full executor (not the dry-run walk): zero-work kernels, real
    // sends/receives/stash/prefetch — its byte counters must still equal
    // the plan-predicted totals exactly, in both send-path modes and at
    // both prefetch depths
    let p = 4usize;
    let n = p * C;
    let q = Tensor::zeros(&[H, n, D]);
    let kv = Tensor::zeros(&[KVH, n, D]);
    let do_ = Tensor::zeros(&[H, n, D]);
    for kind in [ScheduleKind::Ring, ScheduleKind::Balanced] {
        let (fwd, bwd) = Session::new(RunSpec::plans_only(kind, p))
            .unwrap()
            .plans()
            .unwrap();
        let plan_bytes =
            fwd.total_bytes(&wire_cost(Pass::Forward)) + bwd.total_bytes(&wire_cost(Pass::Backward));
        for deep in [false, true] {
            let mut spec = RunSpec::for_plans(&fwd, BackendSpec::Null, &q, &kv);
            spec.trace = true;
            spec.deep_copy_sends = deep;
            let mut session = Session::with_plans(spec, fwd.clone(), bwd.clone()).unwrap();
            session.execute_with(&q, &kv, &kv, Some(&do_)).unwrap();
            let run = session.take_run().unwrap();
            assert_eq!(
                run.result.comm_bytes, plan_bytes as u64,
                "{kind:?} deep={deep}: executor bytes diverge from plan prediction"
            );
            // every transfer op was traced by its sender
            let ft = run.fwd_trace.unwrap();
            for (i, node) in fwd.ops.iter().enumerate() {
                if matches!(node.op, PlanOp::Xfer { .. }) {
                    assert!(ft.covered[i], "{kind:?}: transfer op {i} untraced");
                }
            }
        }
    }
}

#[test]
fn recv_deadline_times_out_instead_of_hanging() {
    // rank 0 never sends: the armed receive must come back with a
    // structured timeout, not block the thread forever
    let mut comms = build_network(2);
    let mut rx = comms.pop().unwrap(); // rank 1
    let _quiet = comms.pop().unwrap(); // rank 0, alive but silent
    let start = Instant::now();
    let err = rx
        .recv_deadline(0, Tag::new(Tag::KV, 0, 0), Some(Duration::from_millis(200)))
        .unwrap_err();
    assert!(
        matches!(err, CommError::Timeout { from: 0, .. }),
        "want Timeout from rank 0, got: {err}"
    );
    if let CommError::Timeout { waited_s, .. } = err {
        assert!(waited_s >= 0.2, "timed out early after {waited_s}s");
    }
    assert!(start.elapsed() < Duration::from_secs(30), "watchdog must fire promptly");
}

#[test]
fn retransmitted_duplicates_deliver_exactly_once() {
    // pick a seed whose very first injection verdict fans the send into
    // >= 2 dup-flagged wire copies (the draw stream is deterministic, so
    // the armed comm below replays the identical decision)
    let spec_for = |seed: u64| FaultSpec {
        seed,
        drop_prob: 1.0,
        max_retransmits: 4,
        ..FaultSpec::default()
    };
    let seed = (0..256)
        .find(|&s| RankFaults::new(0, &spec_for(s)).on_send(1, Tag::new(Tag::KV, 0, 0)).copies >= 2)
        .expect("some seed in 0..256 retransmits on the first send");
    let mut comms = build_network(2);
    let mut rx = comms.pop().unwrap(); // rank 1
    let mut tx = comms.pop().unwrap(); // rank 0
    tx.set_faults(RankFaults::new(0, &spec_for(seed)));
    let t1 = Tag::new(Tag::KV, 0, 0);
    let t2 = Tag::new(Tag::KV, 0, 1);
    tx.send(1, t1, vec![Tensor::full(&[4], 1.0)]).unwrap();
    tx.send(1, t2, vec![Tensor::full(&[4], 2.0)]).unwrap();
    tx.flush_sends().unwrap();
    // the first copy delivers the payload once...
    let got = rx.recv_deadline(0, t1, Some(Duration::from_secs(5))).unwrap();
    assert!(got[0].data().iter().all(|&x| x == 1.0), "t1 payload corrupted");
    // ...the next receive absorbs t1's trailing duplicates silently...
    let got = rx.recv_deadline(0, t2, Some(Duration::from_secs(5))).unwrap();
    assert!(got[0].data().iter().all(|&x| x == 2.0), "t2 payload corrupted");
    // ...and t1 is never re-delivered: its duplicates were deduped on
    // arrival, not stashed for a later receive
    let err = rx.recv_deadline(0, t1, Some(Duration::from_millis(100))).unwrap_err();
    assert!(matches!(err, CommError::Timeout { .. }), "dup re-delivered: {err}");
    // and the sender's event log proves a retransmit actually happened
    let evs = tx.take_fault_events();
    assert!(
        evs.iter()
            .any(|e| matches!(e, FaultEvent::Retransmitted { copies, .. } if *copies >= 2)),
        "no retransmit event logged: {evs:?}"
    );
}

#[test]
fn tags_unique_across_calls_and_disjoint_from_collectives() {
    let p = 8;
    for kind in [ScheduleKind::Ring, ScheduleKind::Balanced] {
        let s = Schedule::build(kind, p);
        let mut seen: HashSet<(usize, usize, Tag)> = HashSet::new();
        for (call, pass) in [(0u32, Pass::Forward), (1, Pass::Backward)] {
            for (src, dst, tag) in s.lower(pass).wire_tags(call) {
                assert!(
                    seen.insert((src, dst, tag)),
                    "{kind:?}: duplicate tag {tag:?} on {src}->{dst}"
                );
            }
        }
        for (_, _, tag) in seen.iter() {
            assert!(
                tag.space != Tag::ALL_REDUCE
                    && tag.space != Tag::GATHER
                    && tag.space != Tag::BARRIER,
                "{kind:?}: schedule traffic leaked into a collective space"
            );
        }
    }
}
