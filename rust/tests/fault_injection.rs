//! Fault-tolerant runtime acceptance suite (ISSUE: robustness PR).
//!
//! * seeded delay/reorder and drop/retransmit faults leave the distributed
//!   outputs **bit-identical** to the fault-free run (at-least-once
//!   delivery + receiver dedup = exactly-once);
//! * a rank-crash fault surfaces as structured [`ExecError`]s on every
//!   rank — the crashed rank reports [`ExecError::InjectedCrash`], every
//!   survivor unwinds into [`ExecError::PeerFailed`] (or a watchdog
//!   [`ExecError::Timeout`]) well inside the deadline, never a hang;
//! * the same [`FaultSpec`] seed reproduces the same fault event sequence
//!   across runs;
//! * a pinned straggler slows both the event-engine prediction
//!   ([`PlanSim::set_worker_slowdown`]) and the measured run, without
//!   changing any output value.

use std::time::{Duration, Instant};

use distflash::config::ClusterSpec;
use distflash::coordinator::{
    CrashSpec, DistAttnResult, ExecError, FailureReport, FaultEvent, FaultSpec, OptimizeOpts,
    OptimizePolicy, Pass, Plan, RunSpec, Schedule, ScheduleKind, Session, Workload,
};
use distflash::simulator::{AttnCost, PlanSim};

/// HostRef spec on the 2x8 (16-worker) layout with a small GQA workload —
/// big enough that every rank exchanges KV, Q-bundle, and helper-result
/// traffic on both passes, small enough to run in milliseconds.
fn host_spec_2x8() -> RunSpec {
    RunSpec::host(ScheduleKind::Balanced, 16, Workload::new(2, 1, 8, 16))
}

/// Execute with synthesized inputs and return (results, injected events).
fn run_2x8(faults: Option<FaultSpec>) -> (DistAttnResult, Vec<FaultEvent>) {
    let mut spec = host_spec_2x8();
    spec.faults = faults;
    let mut session = Session::new(spec).unwrap();
    session.execute().unwrap();
    let events = session.fault_events().to_vec();
    (session.take_run().unwrap().result, events)
}

fn assert_results_identical(got: &DistAttnResult, base: &DistAttnResult, what: &str) {
    assert!(got.o == base.o, "{what}: output o diverged from the fault-free run");
    assert!(got.lse == base.lse, "{what}: lse diverged from the fault-free run");
    let (dq, dk, dv) = got.grads.as_ref().expect("backward ran");
    let (bq, bk, bv) = base.grads.as_ref().expect("backward ran");
    assert!(dq == bq, "{what}: dq diverged from the fault-free run");
    assert!(dk == bk, "{what}: dk diverged from the fault-free run");
    assert!(dv == bv, "{what}: dv diverged from the fault-free run");
}

#[test]
fn seeded_message_faults_leave_outputs_bit_identical() {
    let (base, base_events) = run_2x8(None);
    assert!(base_events.is_empty(), "fault-free run must inject nothing");

    // probability-1 single-class specs make the event assertions
    // deterministic; chaos() is the mixed scenario from the CLI.
    let delay = FaultSpec { seed: 7, delay_prob: 1.0, delay_sends: 3, ..FaultSpec::default() };
    let drop = FaultSpec { seed: 11, drop_prob: 1.0, max_retransmits: 3, ..FaultSpec::default() };
    let classes: [(&str, FaultSpec, fn(&FaultEvent) -> bool); 3] = [
        ("delay/reorder", delay, |e| matches!(e, FaultEvent::Delayed { .. })),
        ("drop/retransmit", drop, |e| matches!(e, FaultEvent::Retransmitted { .. })),
        ("chaos", FaultSpec::chaos(42), |e| {
            matches!(e, FaultEvent::Delayed { .. } | FaultEvent::Retransmitted { .. })
        }),
    ];
    for (what, faults, expected) in classes {
        let (got, events) = run_2x8(Some(faults));
        assert!(events.iter().any(expected), "{what}: expected fault class never fired");
        assert_results_identical(&got, &base, what);
    }
}

#[test]
fn rank_crash_yields_structured_errors_on_every_rank() {
    const P: usize = 8;
    const CRASH_RANK: usize = 3;
    const CRASH_STEP: usize = 2;
    const WATCHDOG_S: f64 = 30.0;

    // hard no-hang guard: the run executes on a helper thread and must
    // report back well inside the watchdog, or this test fails on the
    // channel timeout instead of hanging the suite.
    let (tx, rx) = std::sync::mpsc::channel();
    let t0 = Instant::now();
    std::thread::spawn(move || {
        let mut spec = RunSpec::host(ScheduleKind::Balanced, P, Workload::new(2, 1, 8, 16));
        spec.faults = Some(FaultSpec {
            crash: Some(CrashSpec { rank: CRASH_RANK, step: CRASH_STEP, pass: Pass::Forward }),
            watchdog_s: Some(WATCHDOG_S),
            ..FaultSpec::default()
        });
        let mut session = Session::new(spec).unwrap();
        let err = match session.execute() {
            Ok(_) => panic!("a crash fault must fail the run"),
            Err(e) => e,
        };
        let report = session.failure_report().expect("failed run leaves a report").clone();
        tx.send((format!("{err:#}"), report)).unwrap();
    });
    let (err, report) = rx
        .recv_timeout(Duration::from_secs(120))
        .expect("crash run hung past the hard timeout");
    assert!(
        t0.elapsed() < Duration::from_secs_f64(WATCHDOG_S),
        "recovery took {:?}, longer than the {WATCHDOG_S}s watchdog",
        t0.elapsed()
    );
    assert!(!err.is_empty());

    assert_eq!(report.failures.len(), P, "every rank must fail: {:?}", report.failures);
    let crashes: Vec<&ExecError> = report
        .failures
        .iter()
        .filter(|e| matches!(e, ExecError::InjectedCrash { .. }))
        .collect();
    assert_eq!(crashes.len(), 1, "exactly one injected crash: {:?}", report.failures);
    assert!(
        matches!(crashes[0], ExecError::InjectedCrash { rank: CRASH_RANK, step: CRASH_STEP }),
        "crash attribution wrong: {:?}",
        crashes[0]
    );
    for e in &report.failures {
        assert!(
            matches!(
                e,
                ExecError::InjectedCrash { rank: CRASH_RANK, .. }
                    | ExecError::PeerFailed { rank: CRASH_RANK, .. }
                    | ExecError::Timeout { from: CRASH_RANK, .. }
            ),
            "survivor failure not attributed to the crashed rank: {e:?}"
        );
    }
    assert!(
        matches!(
            report.root_cause(),
            Some(ExecError::InjectedCrash { rank: CRASH_RANK, step: CRASH_STEP })
        ),
        "root cause must be the injected crash: {:?}",
        report.root_cause()
    );
}

#[test]
fn same_seed_reproduces_the_same_fault_event_sequence() {
    let (_, first) = run_2x8(Some(FaultSpec::chaos(1234)));
    let (_, second) = run_2x8(Some(FaultSpec::chaos(1234)));
    assert!(!first.is_empty(), "chaos spec must inject events");
    assert_eq!(first, second, "same seed must reproduce the same event sequence");
}

#[test]
fn plan_sim_slowdown_raises_predicted_makespan() {
    let sched = Schedule::balanced(8);
    let plan = Plan::from_schedule(&sched, Pass::Forward);
    let cluster = ClusterSpec::dgx_2x8();
    let cost = AttnCost {
        pair_full_s: 1e-3,
        pair_diag_s: 5e-4,
        rescale_s: 1e-5,
        kv_bytes: 1e6,
        q_bytes: 5e5,
        result_bytes: 6e5,
        overlap: true,
    };
    let placement: Vec<usize> = (0..8).collect();
    let mut sim = PlanSim::new(&plan, &cost);
    let base = sim.total_s(&cluster, &placement, 1);
    sim.set_worker_slowdown(5, 1.5);
    let stalled = sim.total_s(&cluster, &placement, 1);
    assert!(
        stalled > base,
        "a 1.5x straggler must raise the predicted makespan: {base:.6}s -> {stalled:.6}s"
    );
    assert!(stalled.is_finite());
}

#[test]
fn optimizer_honors_pinned_straggler_slowdowns() {
    let mut spec = RunSpec::plans_only(ScheduleKind::Balanced, 8);
    spec.optimize = OptimizePolicy::Schedule(OptimizeOpts {
        seed: 3,
        slowdowns: vec![(3, 2.0)],
        ..OptimizeOpts::default()
    });
    let mut session = Session::new(spec).unwrap();
    session.optimize().unwrap();
    assert!(session.sim_calls() > 0, "the degradation-aware search must score candidates");
    assert!(!session.audits().is_empty());

    // a slowdown pinned to an out-of-range rank is a spec error, caught
    // before any worker launches
    let mut bad = RunSpec::plans_only(ScheduleKind::Balanced, 4);
    bad.optimize = OptimizePolicy::Schedule(OptimizeOpts {
        slowdowns: vec![(4, 2.0)],
        ..OptimizeOpts::default()
    });
    assert!(Session::new(bad).is_err(), "slowdown rank 4 of 4 workers must be rejected");
}

/// Watchdog boundary, pinned from both sides: a straggler whose per-recv
/// waits stay under the deadline (derived stall-scaled budget, then an
/// explicit budget comfortably above the measured stalled wall) completes
/// with bit-identical outputs; the same straggler pushed far past a tight
/// explicit deadline trips [`ExecError::Timeout`] attributed to the
/// stalled rank. Every arm runs on a helper thread under a hard timeout,
/// so a watchdog regression is a named failure, never a hung suite.
#[test]
fn watchdog_boundary_straggler_under_and_over() {
    const P: usize = 4;
    const STRAGGLER: usize = 1;

    type RunOut = (Result<DistAttnResult, String>, f64, Option<FailureReport>);
    let run = |faults: Option<FaultSpec>| -> RunOut {
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let mut spec = RunSpec::host(ScheduleKind::Balanced, P, Workload::new(4, 2, 32, 192));
            spec.faults = faults;
            let mut session = Session::new(spec).unwrap();
            let t0 = Instant::now();
            let res = session.execute().map(|_| ());
            let wall = t0.elapsed().as_secs_f64();
            let report = session.failure_report().cloned();
            let out = match res {
                Ok(()) => Ok(session.take_run().unwrap().result),
                Err(e) => Err(format!("{e:#}")),
            };
            tx.send((out, wall, report)).unwrap();
        });
        rx.recv_timeout(Duration::from_secs(120)).expect("watchdog run hung past the hard timeout")
    };

    let (base, _, _) = run(None);
    let base = base.expect("fault-free run succeeds");

    // derived budget: the watchdog scales with the pinned stall factor, so
    // a deliberate 3x straggler is not misread as a hang
    let stalled = FaultSpec { stalls: vec![(STRAGGLER, 3.0)], ..FaultSpec::default() };
    let (got, stalled_wall, _) = run(Some(stalled.clone()));
    let got = got.expect("straggler under the derived stall-scaled deadline must complete");
    assert_results_identical(&got, &base, "3x straggler, derived watchdog");

    // under side: explicit budget comfortably above the measured stalled
    // wall — every per-recv wait sits inside the deadline
    let under =
        FaultSpec { watchdog_s: Some((3.0 * stalled_wall).max(2.0)), ..stalled.clone() };
    let (got, _, report) = run(Some(under));
    let got = got.expect("straggler just under the recv deadline must complete");
    assert_results_identical(&got, &base, "straggler under explicit watchdog");
    assert!(report.is_none(), "a completed run must not leave a failure report");

    // over side: the straggler's per-op delay dwarfs a tight explicit
    // deadline — the peers' recv watchdog must trip, attributed to the
    // stalled rank, never a hang
    let over = FaultSpec {
        stalls: vec![(STRAGGLER, 500.0)],
        watchdog_s: Some(0.02),
        ..FaultSpec::default()
    };
    let (res, _, report) = run(Some(over));
    assert!(res.is_err(), "a straggler past the recv deadline must fail the run");
    let report = report.expect("failed run leaves a failure report");
    assert!(
        report
            .failures
            .iter()
            .any(|e| matches!(e, ExecError::Timeout { from: STRAGGLER, .. })),
        "no watchdog timeout attributed to the stalled rank: {:?}",
        report.failures
    );
}

#[test]
fn stalled_rank_slows_execution_and_preserves_outputs() {
    // median-of-3 wall clocks on each arm keep scheduler noise out of the
    // direction check; the 8x factor makes the gap unmistakable.
    let run = |faults: Option<FaultSpec>| {
        let mut spec = RunSpec::host(ScheduleKind::Balanced, 4, Workload::new(4, 2, 32, 192));
        spec.faults = faults;
        let mut session = Session::new(spec).unwrap();
        let mut secs = Vec::new();
        for _ in 0..3 {
            let t0 = Instant::now();
            session.execute().unwrap();
            secs.push(t0.elapsed().as_secs_f64());
        }
        secs.sort_by(|a, b| a.total_cmp(b));
        let events = session.fault_events().to_vec();
        (session.take_run().unwrap().result, secs[1], events)
    };
    let (base, base_s, _) = run(None);
    let stall = FaultSpec { stalls: vec![(1, 8.0)], ..FaultSpec::default() };
    let (got, stall_s, events) = run(Some(stall));
    assert!(
        events.iter().any(|e| matches!(e, FaultEvent::Stalled { rank: 1, .. })),
        "stall event never recorded: {events:?}"
    );
    assert_results_identical(&got, &base, "8x straggler");
    assert!(
        stall_s > base_s,
        "an 8x straggler must slow the measured run: {base_s:.4}s -> {stall_s:.4}s"
    );
}
