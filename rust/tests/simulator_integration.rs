//! Simulator/baseline integration: cross-model consistency checks that
//! mirror the paper's headline claims (the table-level shape, not absolute
//! seconds). These run without artifacts — pure analytic models.

use distflash::baselines::distflash::DistFlashAttn;
use distflash::baselines::megatron::Megatron;
use distflash::baselines::ring_attention::RingAttention;
use distflash::baselines::rsa::RingSelfAttention;
use distflash::baselines::ulysses::Ulysses;
use distflash::baselines::SystemModel;
use distflash::config::{ClusterSpec, PaperModel};
use distflash::coordinator::{CkptStrategy, ScheduleKind};
use distflash::memory::max_total_seq_pow2;

#[test]
fn headline_we_beat_every_baseline_at_long_context() {
    let model = PaperModel::llama_7b();
    let cluster = ClusterSpec::dgx_2x8();
    let seq = 32768;
    let ours = DistFlashAttn::default().iteration(&model, &cluster, seq).total_s();
    let others: Vec<(String, f64)> = vec![
        ("megatron".into(), Megatron::tp().iteration(&model, &cluster, seq).total_s()),
        ("ulysses".into(), Ulysses.iteration(&model, &cluster, seq).total_s()),
        ("ring-attn".into(), RingAttention.iteration(&model, &cluster, seq).total_s()),
        ("rsa".into(), RingSelfAttention.iteration(&model, &cluster, seq).total_s()),
    ];
    for (name, t) in others {
        assert!(t > ours, "{name}: {t} should exceed ours {ours}");
    }
}

#[test]
fn table1_shape_speedup_grows_with_seq_and_irregular_heads() {
    let cluster = ClusterSpec::dgx_2x8();
    let speedup = |m: &PaperModel, s: usize| {
        Megatron::tp().iteration(m, &cluster, s).total_s()
            / DistFlashAttn::default().iteration(m, &cluster, s).total_s()
    };
    let m7 = PaperModel::llama_7b();
    let m33 = PaperModel::llama_33h();
    // we win at every length, in the paper's 1.1-2.0x band. (The paper's
    // *rising*-with-seq trend partly reflects short-seq framework
    // overheads the analytic model does not include — recorded as a
    // deviation in EXPERIMENTS.md.)
    for seq in [8192, 16384, 32768] {
        let s = speedup(&m7, seq);
        assert!((1.05..2.2).contains(&s), "7B @{seq}: {s}");
    }
    // irregular heads amplify our advantage (paper: up to 2.01x)
    assert!(speedup(&m33, 16384) > speedup(&m7, 16384) * 1.2);
}

#[test]
fn table2_shape_ours_insensitive_to_head_count() {
    let cluster = ClusterSpec::cluster_16x40g();
    let ours = DistFlashAttn::default();
    let m16 = max_total_seq_pow2(&ours, &PaperModel::llama_nh(16), &cluster);
    let m2 = max_total_seq_pow2(&ours, &PaperModel::llama_nh(2), &cluster);
    // sequence parallelism does not care about head count (Table 2 row 3)
    assert!(
        (m2 as f64 / m16 as f64) >= 0.5,
        "ours collapses with fewer heads: 16H {m16} vs 2H {m2}"
    );
    // Megatron TP+DP degrades as heads shrink (Table 2 row 1)
    let g16 = max_total_seq_pow2(&Megatron::tp_dp(), &PaperModel::llama_nh(16), &cluster);
    let g2 = max_total_seq_pow2(&Megatron::tp_dp(), &PaperModel::llama_nh(2), &cluster);
    assert!(g2 < g16, "megatron TP+DP should shrink: 16H {g16} 2H {g2}");
    // and we dominate at 2 heads (paper: 512K vs 64K)
    assert!(m2 >= g2 * 4, "ours {m2} vs megatron {g2}");
}

#[test]
fn table2_shape_pp_beats_dp_on_memory_at_low_heads() {
    // paper Table 2: TP+PP supports longer sequences than TP+DP for 4H/2H
    let cluster = ClusterSpec::cluster_16x40g();
    for heads in [4usize, 2] {
        let m = PaperModel::llama_nh(heads);
        let dp = max_total_seq_pow2(&Megatron::tp_dp(), &m, &cluster);
        let pp = max_total_seq_pow2(&Megatron::tp_pp(), &m, &cluster);
        assert!(pp >= dp, "{heads}H: pp {pp} < dp {dp}");
    }
}

#[test]
fn ablation_each_optimization_helps() {
    let model = PaperModel::llama_7b();
    let cluster = ClusterSpec::dgx_2x8();
    let seq = 16384;
    let full = DistFlashAttn::default();
    let no_balance = DistFlashAttn { schedule: ScheduleKind::Ring, ..full };
    let no_overlap = DistFlashAttn { overlap: false, ..full };
    let no_remat = DistFlashAttn { ckpt: CkptStrategy::HfStyle, ..full };
    let t = |s: &DistFlashAttn| s.iteration(&model, &cluster, seq).total_s();
    let base = t(&full);
    assert!(t(&no_balance) > base * 1.15, "balancing contributes (paper ~2x on attention)");
    assert!(t(&no_overlap) > base * 1.02, "overlap contributes (paper 1.32x e2e)");
    assert!(t(&no_remat) > base * 1.10, "remat-aware ckpt contributes (paper 1.24x @16K)");
}

#[test]
fn gqa_speedup_exceeds_mha_speedup_cross_node() {
    // paper §4.1: GQA cuts our kv comm 4x while Megatron's comm is
    // unchanged -> our relative advantage grows (1.46x vs 1.12x @8K 2x8)
    let cluster = ClusterSpec::dgx_2x8();
    let ratio = |m: &PaperModel| {
        Megatron::tp().iteration(m, &cluster, 8192).total_s()
            / DistFlashAttn::default().iteration(m, &cluster, 8192).total_s()
    };
    assert!(ratio(&PaperModel::llama_gqa()) > ratio(&PaperModel::llama_7b()));
}

#[test]
fn fig4_right_overhead_drops_with_overlap() {
    // paper: 105% -> 44% comm overhead at 128K total on 2x8
    let model = PaperModel::llama_7b();
    let cluster = ClusterSpec::dgx_2x8();
    let c = 131072 / 16;
    let on = DistFlashAttn::default().attn_sim(&model, &cluster, c, false);
    let off = DistFlashAttn { overlap: false, ..DistFlashAttn::default() }
        .attn_sim(&model, &cluster, c, false);
    assert!(off.total_s / on.total_s > 1.2, "overlap gain too small");
}

#[test]
fn rsa_oom_where_we_fit() {
    let model = PaperModel::llama_7b();
    let cluster = ClusterSpec::dgx_1x8();
    let seq = 16384; // 128K total — beyond RSA's 32K ceiling
    assert!(!RingSelfAttention.iteration(&model, &cluster, seq).fits(&cluster));
    assert!(DistFlashAttn::default().iteration(&model, &cluster, seq).fits(&cluster));
}
