//! Cross-engine agreement: the event-driven engine running a lowered plan
//! must reproduce the lock-step BSP engine — totals, busy time, and byte
//! counts — at prefetch depth 1 (overlap) and depth 0 (serialized), for
//! the existing ring and balanced schedules across `P = 1..=64`; and
//! deeper prefetch must never be slower.

use distflash::config::ClusterSpec;
use distflash::coordinator::{Pass, Schedule, ScheduleKind};
use distflash::simulator::{simulate_attention, simulate_plan, AttnCost, EventOpts};

const KINDS: [ScheduleKind; 2] = [ScheduleKind::Ring, ScheduleKind::Balanced];

fn cost(overlap: bool) -> AttnCost {
    AttnCost {
        pair_full_s: 1e-3,
        pair_diag_s: 0.5e-3,
        rescale_s: 1e-5,
        kv_bytes: 1e6,
        q_bytes: 0.5e6,
        result_bytes: 0.6e6,
        overlap,
    }
}

fn rel_diff(a: f64, b: f64) -> f64 {
    (a - b).abs() / a.abs().max(b.abs()).max(1e-30)
}

#[test]
fn depth1_matches_lockstep_overlap_all_p() {
    let cluster = ClusterSpec::dgx_2x8();
    for p in 1..=64 {
        for kind in KINDS {
            let s = Schedule::build(kind, p);
            let plan = s.lower(Pass::Forward);
            let a = simulate_attention(&s, &cluster, &cost(true));
            let b = simulate_plan(&plan, &cluster, &cost(true), &EventOpts { prefetch_depth: 1 });
            assert!(
                rel_diff(a.total_s, b.total_s) < 1e-9,
                "{kind:?} P={p}: lockstep {} vs event {}",
                a.total_s,
                b.total_s
            );
            assert!(rel_diff(a.busy_s, b.busy_s) < 1e-9, "{kind:?} P={p} busy");
            assert!(
                rel_diff(a.comm_bytes, b.comm_bytes) < 1e-9,
                "{kind:?} P={p} bytes: {} vs {}",
                a.comm_bytes,
                b.comm_bytes
            );
        }
    }
}

#[test]
fn depth0_matches_lockstep_serial_all_p() {
    let cluster = ClusterSpec::dgx_2x8();
    for p in 1..=64 {
        for kind in KINDS {
            let s = Schedule::build(kind, p);
            let plan = s.lower(Pass::Forward);
            let a = simulate_attention(&s, &cluster, &cost(false));
            let b = simulate_plan(&plan, &cluster, &cost(false), &EventOpts { prefetch_depth: 0 });
            assert!(
                rel_diff(a.total_s, b.total_s) < 1e-9,
                "{kind:?} P={p}: lockstep {} vs event {}",
                a.total_s,
                b.total_s
            );
        }
    }
}

#[test]
fn deeper_prefetch_never_slower_all_p() {
    let cluster = ClusterSpec::dgx_2x8();
    for p in [2usize, 3, 8, 16, 33, 64] {
        for kind in KINDS {
            let plan = Schedule::build(kind, p).lower(Pass::Forward);
            let mut prev = simulate_plan(
                &plan,
                &cluster,
                &cost(true),
                &EventOpts { prefetch_depth: 1 },
            )
            .total_s;
            for d in [2usize, 4, 8, 16] {
                let t = simulate_plan(
                    &plan,
                    &cluster,
                    &cost(true),
                    &EventOpts { prefetch_depth: d },
                )
                .total_s;
                assert!(t <= prev + 1e-12, "{kind:?} P={p} depth {d}: {t} > {prev}");
                prev = t;
            }
        }
    }
}

#[test]
fn backward_lowering_matches_lockstep_at_depth1() {
    // under overlap the (dk, dv) returns ride the comm stream at zero
    // exposed cost, so the backward lowering's wall-clock agrees with the
    // legacy engine too — while its byte count correctly includes the
    // return traffic the legacy engine cannot model
    let cluster = ClusterSpec::dgx_2x8();
    for p in [1usize, 2, 3, 8, 16, 31, 64] {
        for kind in KINDS {
            let s = Schedule::build(kind, p);
            let plan = s.lower(Pass::Backward);
            let a = simulate_attention(&s, &cluster, &cost(true));
            let b = simulate_plan(&plan, &cluster, &cost(true), &EventOpts { prefetch_depth: 1 });
            assert!(
                rel_diff(a.total_s, b.total_s) < 1e-9,
                "{kind:?} P={p}: {} vs {}",
                a.total_s,
                b.total_s
            );
            if p >= 2 {
                assert!(
                    b.comm_bytes > a.comm_bytes,
                    "{kind:?} P={p}: backward plan must count grad returns"
                );
            }
        }
    }
}
