//! Checkpointing in the IR (§3.3): properties of the recompute lowering,
//! the memory timeline, and the joint checkpoint × prefetch search — all
//! on a bare checkout (HostRef kernels, no artifacts).
//!
//! * The HfStyle backward plan's recompute prefix is the forward lowering
//!   verbatim, and executing it on HostRef is bit-identical to the
//!   no-recompute (RematAware) path and matches the `full_attn_ref`
//!   oracle.
//! * The event engine's memory timeline prices RematAware's
//!   `extra_saved_floats` exactly: at prefetch depth 0 the staged
//!   component is identical between strategies, so the peak gap is the
//!   checkpoint bytes and nothing else.
//! * At the paper's 64K-token 2×8 regime the joint search picks
//!   RematAware on time while HfStyle keeps the lower peak, and every
//!   accepted arm fits in `GpuSpec::mem_bytes`.

use distflash::baselines::attn_cost_bwd;
use distflash::config::{ClusterSpec, PaperModel, ELEM_BYTES};
use distflash::coordinator::{
    optimize_ckpt, CkptStrategy, LowerOpts, OptimizeOpts, OptimizePolicy, Pass, Plan, PlanIndex,
    RunSpec, Schedule, ScheduleKind, Session, VarlenSpec, Workload,
};
use distflash::runtime::{HostKernels, Kernels, Tensor, Value};
use distflash::simulator::PlanSim;
use distflash::util::Rng;

fn host_spec(p: usize, ckpt: CkptStrategy) -> RunSpec {
    let mut spec = RunSpec::host(ScheduleKind::Balanced, p, Workload::new(2, 2, 16, 32));
    spec.backward = true;
    spec.ckpt = ckpt;
    spec
}

#[test]
fn hf_recompute_is_bit_identical_to_remat_and_matches_oracle() {
    let (h, kvh, d, p, chunk) = (2usize, 2usize, 16usize, 4usize, 32usize);
    let n = p * chunk;
    let mut rng = Rng::new(11);
    let q = Tensor::new(vec![h, n, d], rng.normal_vec(h * n * d));
    let k = Tensor::new(vec![kvh, n, d], rng.normal_vec(kvh * n * d));
    let v = Tensor::new(vec![kvh, n, d], rng.normal_vec(kvh * n * d));
    let do_ = Tensor::new(vec![h, n, d], rng.normal_vec(h * n * d));

    let run = |ckpt: CkptStrategy| {
        let mut s = Session::new(host_spec(p, ckpt)).unwrap();
        s.execute_with(&q, &k, &v, Some(&do_)).unwrap();
        s.take_run().unwrap().result
    };
    let remat = run(CkptStrategy::RematAware);
    let hf = run(CkptStrategy::HfStyle);

    // the recompute prefix replays the exact forward kernel sequence on
    // the exact inputs, so the rebuilt (o, lse) — and therefore every
    // gradient — must be bit-identical, not merely close
    assert_eq!(hf.o.max_abs_diff(&remat.o), 0.0, "o must be bit-identical");
    assert_eq!(hf.lse.max_abs_diff(&remat.lse), 0.0, "lse must be bit-identical");
    let (hdq, hdk, hdv) = hf.grads.unwrap();
    let (rdq, rdk, rdv) = remat.grads.unwrap();
    assert_eq!(hdq.max_abs_diff(&rdq), 0.0, "dq must be bit-identical");
    assert_eq!(hdk.max_abs_diff(&rdk), 0.0, "dk must be bit-identical");
    assert_eq!(hdv.max_abs_diff(&rdv), 0.0, "dv must be bit-identical");

    // and the distributed result matches the monolithic oracle
    let oracle = HostKernels::default()
        .run(
            "full_attn_ref",
            &[Value::F32(q.clone()), Value::F32(k.clone()), Value::F32(v.clone())],
        )
        .unwrap();
    assert!(hf.o.max_abs_diff(&oracle[0]) < 2e-5, "o vs oracle");
    assert!(hf.lse.max_abs_diff(&oracle[1]) < 2e-5, "lse vs oracle");
    assert!(hf.comm_bytes > remat.comm_bytes, "the prefix re-sends kv/q on the wire");
}

#[test]
fn recompute_prefix_is_the_forward_lowering_and_ranks_see_their_share() {
    let schedule = Schedule::balanced(4);
    let fwd = schedule.lower(Pass::Forward);
    let hf_opts = LowerOpts { ckpt: Some(CkptStrategy::HfStyle), ..Default::default() };
    let bwd = Plan::from_schedule_opts(&schedule, Pass::Backward, &hf_opts);
    assert_eq!(
        bwd.recompute_ops,
        fwd.n_ops(),
        "the prefix must be the whole forward op stream"
    );
    // per-rank indices partition the prefix
    let total: usize = (0..4)
        .map(|r| PlanIndex::new(&bwd, r, Pass::Backward).unwrap().n_recompute())
        .sum();
    assert_eq!(total, bwd.recompute_ops, "rank shares must cover the prefix exactly");

    let ra_opts = LowerOpts { ckpt: Some(CkptStrategy::RematAware), ..Default::default() };
    let plain = Plan::from_schedule_opts(&schedule, Pass::Backward, &ra_opts);
    assert_eq!(plain.recompute_ops, 0, "RematAware lowers no prefix");
    for r in 0..4 {
        assert_eq!(PlanIndex::new(&plain, r, Pass::Backward).unwrap().n_recompute(), 0);
    }
}

#[test]
fn remat_peak_exceeds_hf_by_exactly_the_checkpoint_bytes_at_depth_zero() {
    let model = PaperModel::llama_7b();
    let cluster = ClusterSpec::dgx_1x8();
    let p = cluster.n_gpus();
    let chunk = 512usize;
    let cost = attn_cost_bwd(&model, &cluster, chunk as f64);
    let resident = 1e9; // shared floor — any value works, the delta is what's tested
    let extra = CkptStrategy::RematAware.extra_saved_floats(model.n_heads, chunk, model.head_dim)
        as f64
        * ELEM_BYTES;
    let schedule = Schedule::balanced(p);

    let timeline = |strategy: CkptStrategy, floor: f64| {
        let lopts = LowerOpts { ckpt: Some(strategy), ..Default::default() };
        let plan = Plan::from_schedule_opts(&schedule, Pass::Backward, &lopts);
        let mut sim = PlanSim::new(&plan, &cost);
        // depth 0: fully blocking receives, so at most one staged payload
        // is live per worker at a time and the staged peak is the fattest
        // payload — identical between the two lowerings
        sim.total_s(&cluster, &plan.placement, 0);
        sim.mem_timeline(floor)
    };
    let hf = timeline(CkptStrategy::HfStyle, resident);
    let ra = timeline(CkptStrategy::RematAware, resident + extra);

    for w in 0..p {
        assert!(
            (hf.staged_peak(w) - ra.staged_peak(w)).abs() < 1e-6,
            "worker {w}: staged peaks must match at depth 0 ({} vs {})",
            hf.staged_peak(w),
            ra.staged_peak(w)
        );
    }
    let gap = ra.max_peak() - hf.max_peak();
    assert!(
        (gap - extra).abs() < 1.0,
        "peak gap {gap} must equal the checkpoint bytes {extra}"
    );
}

#[test]
fn joint_search_at_64k_picks_remat_and_prices_memory() {
    // the paper's 2×8 A100-40G regime at 64K total tokens — the same
    // configuration `repro bench --json` gates in CI via BENCH_ckpt.json
    let model = PaperModel::llama_7b();
    let cluster = ClusterSpec::cluster_16x40g();
    let p = cluster.n_gpus();
    let chunk = 65536 / p;
    let cost = attn_cost_bwd(&model, &cluster, chunk as f64);
    let resident = distflash::baselines::fsdp_param_bytes(&model, p)
        + (model.n_layers * chunk * model.d_model) as f64 * ELEM_BYTES;
    let extra = model.n_layers as f64
        * CkptStrategy::RematAware.extra_saved_floats(model.n_heads, chunk, model.head_dim)
            as f64
        * ELEM_BYTES;
    let o = optimize_ckpt(
        &Schedule::balanced(p),
        &cluster,
        &cost,
        &OptimizeOpts::default(),
        resident,
        extra,
    );
    let hf = o.arm(CkptStrategy::HfStyle);
    let ra = o.arm(CkptStrategy::RematAware);
    assert_eq!(o.choice, CkptStrategy::RematAware, "remat-aware must win at 64K");
    assert!(ra.total_s < hf.total_s, "remat must be strictly faster than the recompute prefix");
    assert!(hf.peak_bytes < ra.peak_bytes, "HfStyle must keep the lower peak");
    for arm in &o.arms {
        assert!(arm.fits, "{:?}: both strategies fit at 64K on 40GB", arm.strategy);
        assert!(
            arm.peak_bytes <= cluster.gpu.mem_bytes,
            "{:?}: accepted peak must respect GpuSpec::mem_bytes",
            arm.strategy
        );
    }
    // the winner's plan is the prefix-free lowering
    assert_eq!(o.plan.recompute_ops, 0);
}

#[test]
fn varlen_policy_rejects_hf_ckpt() {
    let p = 4usize;
    let mut spec = RunSpec::host(ScheduleKind::Balanced, p, Workload::new(2, 2, 16, 64));
    spec.varlen = Some(VarlenSpec::pack_zipf(8, 64 * p, 1.1, 3, p));
    spec.optimize = OptimizePolicy::Varlen(OptimizeOpts::default());
    spec.ckpt = CkptStrategy::HfStyle;
    let err = Session::new(spec.clone()).err().expect("varlen + HfStyle must be rejected");
    assert!(
        format!("{err:#}").contains("varlen"),
        "error must explain the varlen conflict: {err:#}"
    );
    // same spec with the paper's strategy is accepted
    spec.ckpt = CkptStrategy::RematAware;
    assert!(Session::new(spec).is_ok());
}

#[test]
fn session_lowers_the_prefix_from_the_spec() {
    for (ckpt, want_prefix) in
        [(CkptStrategy::HfStyle, true), (CkptStrategy::RematAware, false)]
    {
        let mut spec = RunSpec::plans_only(ScheduleKind::Balanced, 4);
        spec.ckpt = ckpt;
        let (fwd, bwd) = Session::new(spec).and_then(|mut s| s.plans()).unwrap();
        assert_eq!(fwd.recompute_ops, 0, "forward plans never carry a prefix");
        if want_prefix {
            assert_eq!(bwd.recompute_ops, fwd.n_ops(), "{ckpt:?}");
        } else {
            assert_eq!(bwd.recompute_ops, 0, "{ckpt:?}");
        }
    }
}
