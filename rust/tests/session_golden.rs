//! Golden equivalence suite: every deprecated harness entry point must be
//! **bit-identical** to its `RunSpec`/`Session` translation — the contract
//! that lets the old free functions shrink to shims without any caller
//! observing a change. Covers all four `run_dist_attention*` paths, all
//! three `build_plans*` builders, P ∈ {2, 8}, varlen and uniform layouts,
//! both schedules, traced and deep-copy modes. (PJRT paths self-skip on a
//! bare checkout like every artifact-backed suite.)

#![allow(deprecated)]

use std::path::PathBuf;
use std::sync::Arc;

use distflash::baselines::{attn_cost_bwd, attn_cost_fwd};
use distflash::config::{ClusterSpec, PaperModel};
use distflash::coordinator::{
    build_plans, build_plans_optimized, build_plans_varlen, run_dist_attention,
    run_dist_attention_exec, run_dist_attention_host, run_dist_attention_planned, BackendSpec,
    DistAttnResult, ExecOpts, OptimizeOpts, OptimizePolicy, Plan, RunSpec, ScheduleKind, Session,
    VarlenSpec, Workload,
};
use distflash::runtime::{Runtime, Tensor};
use distflash::util::Rng;

const H: usize = 4;
const KVH: usize = 2;
const D: usize = 8;
const CHUNK: usize = 12;

fn inputs(p: usize, seed: u64) -> (Tensor, Tensor, Tensor, Tensor) {
    let n = p * CHUNK;
    let mut rng = Rng::new(seed);
    (
        Tensor::new(vec![H, n, D], rng.normal_vec(H * n * D)),
        Tensor::new(vec![KVH, n, D], rng.normal_vec(KVH * n * D)),
        Tensor::new(vec![KVH, n, D], rng.normal_vec(KVH * n * D)),
        Tensor::new(vec![H, n, D], rng.normal_vec(H * n * D)),
    )
}

fn assert_plans_eq(a: &(Arc<Plan>, Arc<Plan>), b: &(Arc<Plan>, Arc<Plan>), what: &str) {
    assert_eq!(*a.0, *b.0, "{what}: forward plans differ");
    assert_eq!(*a.1, *b.1, "{what}: backward plans differ");
}

fn assert_results_eq(a: &DistAttnResult, b: &DistAttnResult, what: &str) {
    assert_eq!(a.o, b.o, "{what}: o differs");
    assert_eq!(a.lse, b.lse, "{what}: lse differs");
    assert_eq!(a.comm_bytes, b.comm_bytes, "{what}: comm bytes differ");
    match (&a.grads, &b.grads) {
        (None, None) => {}
        (Some((adq, adk, adv)), Some((bdq, bdk, bdv))) => {
            assert_eq!(adq, bdq, "{what}: dq differs");
            assert_eq!(adk, bdk, "{what}: dk differs");
            assert_eq!(adv, bdv, "{what}: dv differs");
        }
        _ => panic!("{what}: gradient presence differs"),
    }
}

#[test]
fn build_plans_matches_session_plans() {
    for kind in [ScheduleKind::Ring, ScheduleKind::Balanced] {
        for p in [2usize, 8] {
            let legacy = build_plans(kind, p).unwrap();
            let spec = Session::new(RunSpec::plans_only(kind, p)).unwrap().plans().unwrap();
            assert_plans_eq(&legacy, &spec, &format!("build_plans {kind:?} P={p}"));
        }
    }
}

#[test]
fn build_plans_varlen_matches_session_plans() {
    for p in [2usize, 8] {
        for spec in [
            VarlenSpec::uniform(32, p),
            VarlenSpec::pack_zipf(3 * p, 32 * p, 1.2, 7, p),
        ] {
            let legacy = build_plans_varlen(ScheduleKind::Balanced, &spec).unwrap();
            let mut rs = RunSpec::plans_only(ScheduleKind::Balanced, p);
            rs.varlen = Some(spec.clone());
            let session = Session::new(rs).unwrap().plans().unwrap();
            assert_plans_eq(&legacy, &session, &format!("build_plans_varlen P={p}"));
        }
    }
}

#[test]
fn build_plans_optimized_matches_session_plans() {
    let model = PaperModel::llama_gqa();
    let cluster = ClusterSpec::dgx_2x8();
    for p in [2usize, 8] {
        let fwd_cost = attn_cost_fwd(&model, &cluster, 1024.0);
        let bwd_cost = attn_cost_bwd(&model, &cluster, 1024.0);
        let opts = OptimizeOpts::default();
        let legacy = build_plans_optimized(
            ScheduleKind::Balanced,
            p,
            &cluster,
            &fwd_cost,
            &bwd_cost,
            &opts,
        )
        .unwrap();
        let mut rs = RunSpec::plans_only(ScheduleKind::Balanced, p);
        rs.cluster = cluster;
        rs.optimize = OptimizePolicy::Schedule(opts.clone());
        let mut session = Session::new(rs).unwrap();
        session.set_costs(fwd_cost, bwd_cost);
        let got = session.plans().unwrap();
        assert_plans_eq(&legacy, &got, &format!("build_plans_optimized P={p}"));
        // the session accounted for the search it ran
        assert!(session.sim_calls() > 0);
        // ...and both agree with the *direct* optimizer call (the true
        // pre-Session behavior) — the session's acceptance layer must not
        // change what the pass pipeline produces
        let schedule = distflash::coordinator::Schedule::build(ScheduleKind::Balanced, p);
        let direct_fwd = distflash::coordinator::optimize_schedule(
            &schedule,
            distflash::coordinator::Pass::Forward,
            &cluster,
            &fwd_cost,
            &opts,
        )
        .plan;
        let direct_bwd = distflash::coordinator::optimize_schedule(
            &schedule,
            distflash::coordinator::Pass::Backward,
            &cluster,
            &bwd_cost,
            &opts,
        )
        .plan;
        assert_eq!(*got.0, direct_fwd, "P={p}: session fwd differs from direct optimizer");
        assert_eq!(*got.1, direct_bwd, "P={p}: session bwd differs from direct optimizer");
    }
}

#[test]
fn run_dist_attention_host_matches_session_execute() {
    for kind in [ScheduleKind::Ring, ScheduleKind::Balanced] {
        for p in [2usize, 8] {
            let (q, k, v, do_) = inputs(p, 11);
            let (fwd, bwd) = build_plans(kind, p).unwrap();
            let legacy =
                run_dist_attention_host(fwd.clone(), bwd.clone(), &q, &k, &v, Some(&do_)).unwrap();
            let spec = RunSpec::for_plans(&fwd, BackendSpec::HostRef, &q, &k);
            let mut session = Session::with_plans(spec, fwd, bwd).unwrap();
            session.execute_with(&q, &k, &v, Some(&do_)).unwrap();
            let got = session.take_run().unwrap().result;
            assert_results_eq(&legacy, &got, &format!("host {kind:?} P={p}"));
        }
    }
}

#[test]
fn run_dist_attention_host_matches_spec_lowered_session() {
    // the spec-lowered path (no caller plans at all) must also agree:
    // RunSpec::host lowers the same schedule the legacy builder did
    for p in [2usize, 8] {
        let (q, k, v, do_) = inputs(p, 23);
        let (fwd, bwd) = build_plans(ScheduleKind::Balanced, p).unwrap();
        let legacy = run_dist_attention_host(fwd, bwd, &q, &k, &v, Some(&do_)).unwrap();
        let mut session = Session::new(RunSpec::host(
            ScheduleKind::Balanced,
            p,
            Workload::new(H, KVH, D, CHUNK),
        ))
        .unwrap();
        session.execute_with(&q, &k, &v, Some(&do_)).unwrap();
        let got = session.take_run().unwrap().result;
        assert_results_eq(&legacy, &got, &format!("spec-lowered P={p}"));
    }
}

#[test]
fn run_dist_attention_exec_matches_session_all_modes() {
    // trace on/off × deep-copy on/off × Null/HostRef backends
    let p = 8usize;
    let (q, k, v, do_) = inputs(p, 31);
    let (fwd, bwd) = build_plans(ScheduleKind::Balanced, p).unwrap();
    for backend in [BackendSpec::HostRef, BackendSpec::Null] {
        for (trace, deep) in [(false, false), (true, false), (false, true), (true, true)] {
            let opts = ExecOpts {
                backend: backend.clone(),
                trace,
                deep_copy_sends: deep,
                ..ExecOpts::host()
            };
            let legacy =
                run_dist_attention_exec(fwd.clone(), bwd.clone(), &q, &k, &v, Some(&do_), &opts)
                    .unwrap();
            let mut spec = RunSpec::for_plans(&fwd, backend.clone(), &q, &k);
            spec.trace = trace;
            spec.deep_copy_sends = deep;
            let mut session = Session::with_plans(spec, fwd.clone(), bwd.clone()).unwrap();
            session.execute_with(&q, &k, &v, Some(&do_)).unwrap();
            let got = session.take_run().unwrap();
            let what = format!("exec {backend:?} trace={trace} deep={deep}");
            assert_results_eq(&legacy.result, &got.result, &what);
            assert_eq!(legacy.fwd_trace.is_some(), got.fwd_trace.is_some(), "{what}");
            assert_eq!(legacy.bwd_trace.is_some(), got.bwd_trace.is_some(), "{what}");
        }
    }
}

#[test]
fn varlen_exec_matches_session_on_ragged_host_run() {
    // ragged boundaries execute on the host backend; both routes must
    // shard at the same cuts and produce identical bits
    let p = 4usize;
    let mut spec = VarlenSpec::pack_zipf(6, 96, 1.1, 5, p);
    // knock the packing off the equal-token grid so the chunks are
    // genuinely ragged (pack_zipf itself cuts equal-token boundaries)
    spec.boundaries[2] += 3;
    spec.validate().unwrap();
    let n = spec.total_tokens();
    let mut rng = Rng::new(41);
    let q = Tensor::new(vec![H, n, D], rng.normal_vec(H * n * D));
    let k = Tensor::new(vec![KVH, n, D], rng.normal_vec(KVH * n * D));
    let v = Tensor::new(vec![KVH, n, D], rng.normal_vec(KVH * n * D));
    let do_ = Tensor::new(vec![H, n, D], rng.normal_vec(H * n * D));
    let (fwd, bwd) = build_plans_varlen(ScheduleKind::Balanced, &spec).unwrap();
    let legacy =
        run_dist_attention_host(fwd.clone(), bwd.clone(), &q, &k, &v, Some(&do_)).unwrap();
    let mut rs = RunSpec::host(ScheduleKind::Balanced, p, Workload::from_tensors(&q, &k, p));
    rs.varlen = Some(spec);
    let mut session = Session::new(rs).unwrap();
    session.execute_with(&q, &k, &v, Some(&do_)).unwrap();
    let got = session.take_run().unwrap().result;
    assert_results_eq(&legacy, &got, "varlen ragged host");
}

// --- artifact-backed (PJRT) paths: self-skip on a bare checkout ----------

fn artifact_dir(cfg: &str) -> PathBuf {
    let root = std::env::var("CARGO_MANIFEST_DIR").unwrap();
    PathBuf::from(root).join("artifacts").join(cfg)
}

fn have(cfg: &str) -> bool {
    let ok = artifact_dir(cfg).join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts/{cfg} missing (run `make artifacts`)");
    }
    ok
}

#[test]
fn run_dist_attention_pjrt_matches_session() {
    if !have("tiny") {
        return;
    }
    let dir = artifact_dir("tiny");
    let mc = Runtime::load(&dir).unwrap().manifest().config.clone();
    let (h, kvh, n, d, p) = (mc.n_heads, mc.n_kv_heads, mc.seq_len, mc.head_dim, mc.n_workers);
    let mut rng = Rng::new(3);
    let q = Tensor::new(vec![h, n, d], rng.normal_vec(h * n * d));
    let k = Tensor::new(vec![kvh, n, d], rng.normal_vec(kvh * n * d));
    let v = Tensor::new(vec![kvh, n, d], rng.normal_vec(kvh * n * d));
    let do_ = Tensor::new(vec![h, n, d], rng.normal_vec(h * n * d));
    for kind in [ScheduleKind::Ring, ScheduleKind::Balanced] {
        let legacy = run_dist_attention(&dir, kind, p, &q, &k, &v, Some(&do_)).unwrap();
        let mut session = Session::new(RunSpec::pjrt(&dir, kind)).unwrap();
        session.execute_with(&q, &k, &v, Some(&do_)).unwrap();
        let got = session.take_run().unwrap().result;
        assert_results_eq(&legacy, &got, &format!("pjrt {kind:?}"));
        // the planned variant over explicit plans agrees too
        let (fwd, bwd) = build_plans(kind, p).unwrap();
        let planned =
            run_dist_attention_planned(&dir, fwd, bwd, &q, &k, &v, Some(&do_)).unwrap();
        assert_results_eq(&legacy, &planned, &format!("pjrt planned {kind:?}"));
    }
}
