//! The measure→model loop (ISSUE 5 acceptance criterion): a Session on
//! the HostRef backend over the 2×8-dev cluster runs
//! `execute().calibrate().optimize()` — fitting the cost model's kernel
//! classes from the run's own measured trace and re-optimizing under it —
//! and the recalibrated plan's simulated makespan under the *measured*
//! cost model must be ≤ the uncalibrated optimized plan's, with the
//! `sim_calls` search budget reported.

use distflash::config::ClusterSpec;
use distflash::coordinator::{
    OptimizeOpts, OptimizePolicy, Plan, RunSpec, ScheduleKind, Session, Workload,
};
use distflash::simulator::{AttnCost, PlanSim};

fn score(plan: &Plan, cluster: &ClusterSpec, cost: &AttnCost) -> f64 {
    PlanSim::new(plan, cost).total_s(cluster, &plan.placement, plan.prefetch_depth)
}

#[test]
fn calibrated_reoptimize_never_worse_under_measured_costs() {
    let cluster = ClusterSpec::cluster_16x40g(); // the 2×8-dev preset
    let p = cluster.n_gpus();
    let mut spec = RunSpec::host(ScheduleKind::Balanced, p, Workload::new(4, 2, 16, 24));
    spec.cluster = cluster;
    spec.optimize = OptimizePolicy::Schedule(OptimizeOpts::default());
    spec.trace = true;
    let mut session = Session::new(spec).unwrap();

    // execute() auto-runs plan + optimize under the *modeled* costs, then
    // runs the real threaded executor with per-op tracing
    session.execute().unwrap();
    let (fwd_a, bwd_a) = session.plans().unwrap();
    assert!(!session.calibrated());
    let sims_before = session.sim_calls();
    assert!(sims_before > 0, "the modeled optimize pass spent no sims");

    // the typed-stage chain from the issue: execute().calibrate().optimize()
    session.calibrate().unwrap().optimize().unwrap();
    assert!(session.calibrated());
    let (fwd_cost, bwd_cost) = {
        let (f, b) = session.costs();
        (*f, *b)
    };
    // calibration really measured something: kernel classes are positive
    // and differ from the analytic model's GPU-roofline numbers
    assert!(fwd_cost.pair_full_s > 0.0 && fwd_cost.pair_diag_s > 0.0);
    assert!(bwd_cost.pair_full_s > 0.0);

    let (fwd_b, bwd_b) = session.plans().unwrap();
    // the acceptance bound: under the measured cost model, the
    // recalibrated plans are never worse than the uncalibrated optimized
    // plans (the session only swaps a plan on a non-worse score)
    let a_f = score(&fwd_a, &cluster, &fwd_cost);
    let b_f = score(&fwd_b, &cluster, &fwd_cost);
    assert!(
        b_f <= a_f * (1.0 + 1e-9),
        "fwd: recalibrated {b_f} vs uncalibrated {a_f} under measured costs"
    );
    let a_b = score(&bwd_a, &cluster, &bwd_cost);
    let b_b = score(&bwd_b, &cluster, &bwd_cost);
    assert!(
        b_b <= a_b * (1.0 + 1e-9),
        "bwd: recalibrated {b_b} vs uncalibrated {a_b} under measured costs"
    );

    // sim_calls budget reported and growing across the second search
    let sims_after = session.sim_calls();
    assert!(
        sims_after > sims_before,
        "recalibrated optimize spent no additional sims ({sims_before} -> {sims_after})"
    );
    println!(
        "calibration loop: sim budget {sims_before} (modeled) -> {sims_after} (total); \
         fwd {a_f:.6}s -> {b_f:.6}s, bwd {a_b:.6}s -> {b_b:.6}s under measured costs"
    );

    // both audit trails are on record: a modeled stage and a calibrated one
    let audits = session.audits();
    assert!(audits.iter().any(|a| !a.calibrated));
    assert!(audits.iter().any(|a| a.calibrated));
}

#[test]
fn calibrated_costs_feed_varlen_reoptimization_too() {
    // same loop on a document-packed spec: the varlen rebalancer accepts
    // the (fwd, bwd) pair jointly, so both plans always share one chunking
    let cluster = ClusterSpec::dgx_2x8();
    let p = 8usize;
    let vspec = distflash::coordinator::VarlenSpec::pack_zipf(12, 24 * p, 1.2, 3, p);
    let mut spec = RunSpec::host(ScheduleKind::Balanced, p, Workload::new(2, 1, 8, 24));
    spec.cluster = cluster;
    spec.varlen = Some(vspec);
    spec.optimize = OptimizePolicy::Varlen(OptimizeOpts::default());
    spec.trace = true;
    let mut session = Session::new(spec).unwrap();
    session.execute().unwrap().calibrate().unwrap().optimize().unwrap();
    let (fwd, bwd) = session.plans().unwrap();
    assert_eq!(
        fwd.varlen.as_deref(),
        bwd.varlen.as_deref(),
        "fwd/bwd diverged on chunk boundaries"
    );
    fwd.validate_lowered().unwrap();
    bwd.validate_lowered().unwrap();
    assert!(session.sim_calls() > 0);
}
