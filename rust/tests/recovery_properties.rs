//! Supervised recovery acceptance suite (recovery-beyond-fail-fast PR).
//!
//! * a seeded [`CrashSpec`] at any `(rank, step, pass)` on the 2x8
//!   (16-worker) HostRef layout recovers under both
//!   [`RecoveryPolicy::Respawn`] and [`RecoveryPolicy::Elastic`], and the
//!   recovered outputs are **bit-identical** to the fault-free run —
//!   replay re-executes the original P-chunk plans, so the online-softmax
//!   merge order never changes;
//! * `FailFast` preserves the PR 8 contract exactly: the run fails with a
//!   structured report and leaves no recovery report;
//! * `RunSpec::recovery` round-trips through JSON, and out-of-bounds
//!   crash steps are rejected at validation time with a pinned message.
//!
//! Every executing arm runs on a helper thread under a hard timeout, so a
//! recovery regression surfaces as a named failure, never a hung suite.

use std::sync::mpsc;
use std::time::Duration;

use distflash::coordinator::{
    CrashSpec, DistAttnResult, FaultSpec, Pass, RecoveryPolicy, RecoveryReport, RunSpec, Schedule,
    ScheduleKind, Session, Workload,
};

const P: usize = 16;
const LAYERS: usize = 2;
const HARD_TIMEOUT: Duration = Duration::from_secs(240);

fn host_spec() -> RunSpec {
    let mut spec = RunSpec::host(ScheduleKind::Balanced, P, Workload::new(2, 1, 8, 16));
    spec.layers = LAYERS;
    spec
}

/// One supervised run on a helper thread under the hard no-hang timeout;
/// returns the result tensors (or the rendered error) and the recovery
/// audit.
fn run_supervised(
    faults: Option<FaultSpec>,
    recovery: RecoveryPolicy,
) -> (Result<DistAttnResult, String>, Option<RecoveryReport>) {
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        let mut spec = host_spec();
        spec.faults = faults;
        spec.recovery = recovery;
        let mut session = Session::new(spec).unwrap();
        // map(|_| ()) drops the &mut borrow the supervisor hands back
        let run = session.execute_supervised().map(|_| ());
        let res = match run {
            Ok(()) => Ok(session.take_run().unwrap().result),
            Err(e) => Err(format!("{e:#}")),
        };
        let report = session.recovery_report().cloned();
        tx.send((res, report)).unwrap();
    });
    rx.recv_timeout(HARD_TIMEOUT).expect("supervised run hung past the hard timeout")
}

fn assert_identical(got: &DistAttnResult, base: &DistAttnResult, what: &str) {
    assert!(got.o == base.o, "{what}: output o diverged from the fault-free run");
    assert!(got.lse == base.lse, "{what}: lse diverged from the fault-free run");
    let (dq, dk, dv) = got.grads.as_ref().expect("backward ran");
    let (bq, bk, bv) = base.grads.as_ref().expect("backward ran");
    assert!(dq == bq && dk == bk && dv == bv, "{what}: grads diverged from the fault-free run");
}

/// The acceptance property: crash anywhere, recover everywhere,
/// bit-identical under both policies.
#[test]
fn crash_anywhere_recovers_bit_identical_under_both_policies() {
    let (base, base_report) = run_supervised(None, RecoveryPolicy::FailFast);
    let base = base.expect("fault-free run succeeds");
    assert!(base_report.is_none(), "FailFast must not leave a recovery report");

    let t = Schedule::build(ScheduleKind::Balanced, P).n_steps();
    // RunSpec::host defaults to RematAware, whose backward plan carries no
    // recompute prefix: last in-bounds step is T (trailing accumulate)
    let last = |pass: Pass| match pass {
        Pass::Forward => t - 1,
        Pass::Backward => t,
    };
    let mut restarted = 0usize;
    for pass in [Pass::Forward, Pass::Backward] {
        for rank in [0, P / 2 - 1, P - 1] {
            for step in [0, t / 2, last(pass)] {
                for (pname, policy) in [
                    ("respawn", RecoveryPolicy::respawn()),
                    ("elastic", RecoveryPolicy::Elastic { min_workers: 2 }),
                ] {
                    let what = format!("{pname}: crash rank {rank} step {step} {pass:?}");
                    let faults = FaultSpec {
                        seed: 5,
                        crash: Some(CrashSpec { rank, step, pass }),
                        ..FaultSpec::default()
                    };
                    let (res, report) = run_supervised(Some(faults), policy);
                    let got = match res {
                        Ok(r) => r,
                        Err(e) => panic!("{what}: did not recover: {e}"),
                    };
                    assert_identical(&got, &base, &what);
                    let report =
                        report.unwrap_or_else(|| panic!("{what}: no recovery report"));
                    assert!(report.recovered, "{what}: report must say recovered");
                    if !report.attempts.is_empty() {
                        restarted += 1;
                        assert!(
                            report.attempts.iter().any(|a| a.succeeded),
                            "{what}: a recovered run needs a succeeded attempt: {:?}",
                            report.attempts
                        );
                        assert!(
                            report.replayed_ops > 0,
                            "{what}: a restart must replay ops"
                        );
                        assert!(
                            report.verified,
                            "{what}: replayed chunks must verify against the checkpointed \
                             artifacts"
                        );
                    }
                }
            }
        }
    }
    assert!(
        restarted > 0,
        "at least one (rank, step, pass) combo must exercise a real restart"
    );
}

/// `FailFast` is byte-for-byte the PR 8 contract: the crash fails the
/// run, the failure report names the injected crash, and no recovery
/// report appears.
#[test]
fn fail_fast_preserves_the_fail_fast_contract() {
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        let mut spec = host_spec();
        spec.faults = Some(FaultSpec {
            seed: 5,
            crash: Some(CrashSpec { rank: 3, step: 1, pass: Pass::Forward }),
            ..FaultSpec::default()
        });
        // FailFast is the default policy — leave it untouched
        let mut session = Session::new(spec).unwrap();
        let run = session.execute_supervised().map(|_| ());
        let err = match run {
            Ok(()) => panic!("a crash under FailFast must fail the run"),
            Err(e) => format!("{e:#}"),
        };
        let failure = session.failure_report().cloned();
        let recovery = session.recovery_report().cloned();
        tx.send((err, failure, recovery)).unwrap();
    });
    let (err, failure, recovery) = rx
        .recv_timeout(HARD_TIMEOUT)
        .expect("fail-fast run hung past the hard timeout");
    assert!(err.contains("injected crash"), "error must name the crash: {err}");
    let failure = failure.expect("failed run leaves a failure report");
    assert_eq!(failure.failures.len(), P, "every rank must fail: {:?}", failure.failures);
    assert!(recovery.is_none(), "FailFast must not produce a recovery report");
}

/// Respawn with the crash still armed on every retry can never succeed —
/// the supervisor must exhaust its budget and say so, not loop forever.
/// (The real loop clears one-shot crashes; this pins the exhaustion path
/// via a crash that is *not* the recoverable kind: zero retries allowed.)
#[test]
fn recovery_policy_validation_rejects_degenerate_budgets() {
    let mut spec = host_spec();
    spec.recovery = RecoveryPolicy::Respawn { max_retries: 0, backoff_s: 0.0 };
    let err = Session::new(spec).expect_err("zero retries must be rejected");
    assert!(
        format!("{err:#}").contains("max_retries must be >= 1"),
        "unexpected message: {err:#}"
    );

    let mut spec = host_spec();
    spec.recovery = RecoveryPolicy::Elastic { min_workers: P };
    let err = Session::new(spec).expect_err("min_workers == P must be rejected");
    assert!(
        format!("{err:#}").contains("must be below the worker count"),
        "unexpected message: {err:#}"
    );
}

/// The spec round-trips: every policy survives `to_json` -> `from_json`,
/// and an out-of-bounds crash step is rejected at validation time with
/// the pinned message.
#[test]
fn recovery_spec_roundtrips_and_crash_steps_are_bounded() {
    for policy in [
        RecoveryPolicy::FailFast,
        RecoveryPolicy::Respawn { max_retries: 4, backoff_s: 0.125 },
        RecoveryPolicy::Elastic { min_workers: 3 },
    ] {
        let mut spec = host_spec();
        spec.recovery = policy.clone();
        let parsed = RunSpec::from_json(&spec.to_json()).expect("serialized spec parses");
        assert_eq!(parsed.recovery, policy, "recovery policy must round-trip");
        assert_eq!(parsed, spec, "the whole spec must round-trip");
    }

    // a crash step past the plan's last step would silently never fire:
    // the spec is rejected up front, with the bound in the message
    let t = Schedule::build(ScheduleKind::Balanced, P).n_steps();
    let mut spec = host_spec();
    spec.faults = Some(FaultSpec {
        crash: Some(CrashSpec { rank: 0, step: t + 7, pass: Pass::Forward }),
        ..FaultSpec::default()
    });
    let err = Session::new(spec).expect_err("out-of-bounds crash step must be rejected");
    let msg = format!("{err:#}");
    assert!(
        msg.contains(&format!("crash step {} is past", t + 7)) && msg.contains("last step"),
        "unexpected message: {msg}"
    );
}
