//! Trainer integration: distributed sequence-parallel training must (1)
//! match the monolithic `full_model_grads` autodiff oracle on the first
//! step, (2) produce *identical* losses under both checkpointing
//! strategies (the paper's "no numerical difference" claim, §3.3), (3)
//! produce identical losses under ring vs balanced schedules, and (4)
//! actually learn the synthetic corpus.

use std::path::PathBuf;

use distflash::coordinator::{CkptStrategy, ScheduleKind};
use distflash::train::{oracle_first_step, train, AdamConfig, TrainConfig};

fn artifact_dir(cfg: &str) -> PathBuf {
    let root = std::env::var("CARGO_MANIFEST_DIR").unwrap();
    PathBuf::from(root).join("artifacts").join(cfg)
}

fn have(cfg: &str) -> bool {
    let ok = artifact_dir(cfg).join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts/{cfg} missing (run `make artifacts`)");
    }
    ok
}

fn base_cfg(name: &str, steps: usize) -> TrainConfig {
    TrainConfig {
        steps,
        adam: AdamConfig { lr: 3e-3, ..Default::default() },
        seed: 42,
        ..TrainConfig::new(&artifact_dir(name))
    }
}

#[test]
fn first_step_matches_autodiff_oracle() {
    if !have("tiny") {
        return;
    }
    let cfg = base_cfg("tiny", 1);
    let (oracle_loss, _oracle_grads) = oracle_first_step(&cfg).unwrap();
    let report = train(&cfg).unwrap();
    let got = report.logs[0].loss;
    let rel = (got - oracle_loss).abs() / oracle_loss.abs();
    assert!(
        rel < 1e-4,
        "distributed first-step loss {got} vs oracle {oracle_loss}"
    );
}

#[test]
fn ckpt_strategies_numerically_identical() {
    // §3.3: remat-aware checkpointing introduces NO numerical difference.
    if !have("tiny") {
        return;
    }
    let steps = 4;
    let mut hf = base_cfg("tiny", steps);
    hf.ckpt = CkptStrategy::HfStyle;
    let mut ours = base_cfg("tiny", steps);
    ours.ckpt = CkptStrategy::RematAware;
    let a = train(&hf).unwrap();
    let b = train(&ours).unwrap();
    for (la, lb) in a.logs.iter().zip(&b.logs) {
        assert_eq!(
            la.loss, lb.loss,
            "step {}: HF {} vs remat {}",
            la.step, la.loss, lb.loss
        );
    }
    // and the remat-aware run must move fewer bytes (no fwd re-comm)
    let ab = a.logs.last().unwrap().comm_bytes;
    let bb = b.logs.last().unwrap().comm_bytes;
    assert!(
        bb < ab,
        "remat-aware comm {bb} should be below HF-style {ab}"
    );
}

#[test]
fn schedules_numerically_identical() {
    if !have("tiny") {
        return;
    }
    let steps = 3;
    let mut ring = base_cfg("tiny", steps);
    ring.run.schedule = ScheduleKind::Ring;
    let mut bal = base_cfg("tiny", steps);
    bal.run.schedule = ScheduleKind::Balanced;
    let a = train(&ring).unwrap();
    let b = train(&bal).unwrap();
    for (la, lb) in a.logs.iter().zip(&b.logs) {
        let rel = (la.loss - lb.loss).abs() / la.loss.abs();
        assert!(
            rel < 2e-5,
            "step {}: ring {} vs balanced {}",
            la.step,
            la.loss,
            lb.loss
        );
    }
}

#[test]
fn loss_decreases_on_markov_corpus() {
    if !have("tiny") {
        return;
    }
    let cfg = base_cfg("tiny", 30);
    let report = train(&cfg).unwrap();
    let first = report.logs[0].loss;
    let last = report.logs.last().unwrap().loss;
    // tiny vocab 256: initial loss ~ ln(256) = 5.54; must fall markedly
    assert!(
        (4.5..7.0).contains(&first),
        "initial loss {first} not near ln(V)"
    );
    assert!(
        last < first * 0.8,
        "loss did not decrease: {first} -> {last}"
    );
    assert!(report.logs.iter().all(|l| l.loss.is_finite()));
    assert!(report.logs.iter().all(|l| l.grad_norm.is_finite()));
}

#[test]
fn gqa_trains_too() {
    if !have("tiny-gqa") {
        return;
    }
    let cfg = base_cfg("tiny-gqa", 6);
    let report = train(&cfg).unwrap();
    assert!(report.logs.iter().all(|l| l.loss.is_finite()));
    assert!(report.logs.last().unwrap().loss < report.logs[0].loss);
}

#[test]
fn odd_worker_count_trains() {
    if !have("tiny-p3") {
        return;
    }
    let cfg = base_cfg("tiny-p3", 4);
    let report = train(&cfg).unwrap();
    assert!(report.logs.iter().all(|l| l.loss.is_finite()));
}

#[test]
fn traced_training_step_yields_per_layer_timelines() {
    // RunSpec::trace threads the shared epoch + sink through every
    // worker's attn_call: the final step must produce one merged timeline
    // per (layer, pass), numerically identical to an untraced run
    if !have("tiny") {
        return;
    }
    let steps = 2;
    let plain = base_cfg("tiny", steps);
    let mut traced = base_cfg("tiny", steps);
    traced.run.trace = true;
    let a = train(&plain).unwrap();
    let b = train(&traced).unwrap();
    for (la, lb) in a.logs.iter().zip(&b.logs) {
        assert_eq!(la.loss, lb.loss, "tracing changed the numerics at step {}", la.step);
    }
    assert!(a.layer_traces.is_empty());
    assert!(!b.layer_traces.is_empty(), "traced run produced no layer timelines");
    // one fwd and one bwd timeline per layer (remat-aware: no recompute)
    let fwd = b.layer_traces.iter().filter(|t| t.pass == "fwd").count();
    let bwd = b.layer_traces.iter().filter(|t| t.pass == "bwd").count();
    assert_eq!(fwd, bwd, "unbalanced fwd/bwd timelines");
    assert!(fwd >= 1);
    for lt in &b.layer_traces {
        assert!(lt.trace.makespan_s() > 0.0, "layer {} {} trace is empty", lt.layer, lt.pass);
    }
}

#[test]
fn varlen_uniform_boundaries_train_and_ragged_rejected() {
    // the embedded RunSpec carries the document-packed layout: uniform
    // boundaries run (doc-masked pair skipping applies), ragged ones are
    // rejected up front (fixed-shape AOT artifacts)
    if !have("tiny") {
        return;
    }
    let dir = artifact_dir("tiny");
    let rt = distflash::runtime::Runtime::load(&dir).unwrap();
    let mc = rt.manifest().config.clone();
    drop(rt);
    let (n, p) = (mc.seq_len, mc.n_workers);
    // uniform chunks, one doc spanning everything: must train exactly like
    // the unpacked path (degenerate spec lowers to the classic plan)
    let mut cfg = base_cfg("tiny", 2);
    cfg.run.varlen = Some(distflash::coordinator::VarlenSpec::uniform(n / p, p));
    let packed = train(&cfg).unwrap();
    let plain = train(&base_cfg("tiny", 2)).unwrap();
    for (la, lb) in packed.logs.iter().zip(&plain.logs) {
        assert_eq!(la.loss, lb.loss, "uniform varlen changed the numerics");
    }
    // ragged boundaries: clear upfront error, no deadlocked workers
    let mut ragged = base_cfg("tiny", 1);
    let mut boundaries: Vec<usize> = (0..=p).map(|r| r * (n / p)).collect();
    boundaries[1] += 1; // make chunk 0 one token fatter
    ragged.run.varlen = Some(distflash::coordinator::VarlenSpec {
        doc_lens: vec![n],
        boundaries,
    });
    let err = train(&ragged).unwrap_err();
    assert!(format!("{err:#}").contains("ragged"), "{err:#}");
}
