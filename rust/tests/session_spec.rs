//! `RunSpec` serde contract (`repro run --spec`): exact JSON round-trips
//! for every field shape — floats serialize in Rust's shortest
//! round-trip form, so `from_json(to_json(s)) == s` bit-for-bit — plus
//! preset-name cluster parsing and rejection of malformed documents.

use std::path::PathBuf;

use distflash::config::ClusterSpec;
use distflash::coordinator::{
    BackendSpec, CkptStrategy, CrashSpec, FaultSpec, OptimizeOpts, OptimizePolicy, Pass,
    RecoveryPolicy, RunSpec, ScheduleKind, Session, VarlenSpec, Workload,
};

fn roundtrip(spec: &RunSpec) -> RunSpec {
    let json = spec.to_json();
    RunSpec::from_json(&json)
        .unwrap_or_else(|e| panic!("round-trip parse failed: {e}\n{json}"))
}

#[test]
fn default_host_spec_roundtrips_exactly() {
    let spec = RunSpec::host(ScheduleKind::Balanced, 8, Workload::new(4, 2, 32, 64));
    assert_eq!(roundtrip(&spec), spec);
}

#[test]
fn every_field_shape_roundtrips_exactly() {
    // exercise each serialized variant: pjrt backend, varlen layout, both
    // optimize policies with non-default knobs, overrides on every scalar
    let mut spec = RunSpec::pjrt(&PathBuf::from("artifacts/tiny"), ScheduleKind::Ring);
    assert_eq!(roundtrip(&spec), spec, "manifest-resolved pjrt spec");

    spec = RunSpec::host(ScheduleKind::Balanced, 4, Workload::new(8, 4, 16, 48));
    spec.varlen = Some(VarlenSpec::pack_zipf(6, 4 * 48, 1.3, 11, 4));
    spec.cluster = ClusterSpec::cluster_16x40g();
    spec.optimize = OptimizePolicy::Varlen(OptimizeOpts {
        seed: 9,
        swap_rounds: 5,
        depths: vec![1, 2, 7],
        knee_rel_tol: 0.025,
        stage_mem_frac: 0.125,
        flip: false,
        placement: true,
        rebalance_rounds: 2,
        align_doc_cuts: false,
        move_boundaries: true,
        per_op_costs: true,
        slowdowns: vec![(0, 1.5), (3, 2.0)],
    });
    spec.prefetch_depth = Some(3);
    spec.layers = 4;
    spec.backward = false;
    spec.trace = true;
    spec.deep_copy_sends = true;
    spec.seed = 123;
    assert_eq!(roundtrip(&spec), spec, "varlen + optimize spec");

    spec.backend = BackendSpec::Null;
    spec.varlen = None;
    spec.optimize = OptimizePolicy::Schedule(OptimizeOpts::default());
    spec.ckpt = CkptStrategy::HfStyle;
    spec.faults = Some(FaultSpec {
        seed: 41,
        delay_prob: 0.25,
        delay_sends: 3,
        drop_prob: 0.0625,
        max_retransmits: 4,
        stalls: vec![(1, 1.5)],
        crash: Some(CrashSpec { rank: 2, step: 5, pass: Pass::Backward }),
        watchdog_s: Some(12.5),
    });
    assert_eq!(roundtrip(&spec), spec, "null backend + schedule policy + hf ckpt + faults");
    spec.faults = None;

    // every recovery policy survives the trip, including a fractional
    // backoff that must serialize in shortest-round-trip float form
    for recovery in [
        RecoveryPolicy::FailFast,
        RecoveryPolicy::Respawn { max_retries: 5, backoff_s: 0.125 },
        RecoveryPolicy::Elastic { min_workers: 3 },
    ] {
        spec.recovery = recovery;
        assert_eq!(roundtrip(&spec), spec, "recovery policy {:?}", spec.recovery);
    }
    spec.recovery = RecoveryPolicy::FailFast;

    // seeds above 2^53 cannot ride a JSON f64 — they serialize as decimal
    // strings and still round-trip exactly
    spec.seed = u64::MAX - 1;
    spec.optimize = OptimizePolicy::Schedule(OptimizeOpts {
        seed: (1u64 << 60) + 1,
        ..Default::default()
    });
    assert_eq!(roundtrip(&spec), spec, "u64 seeds beyond 2^53");
}

#[test]
fn cluster_presets_parse_by_name() {
    let json = r#"{
        "workload": {"n_heads": 4, "n_kv_heads": 2, "head_dim": 16, "chunk_tokens": 32},
        "n_workers": 16,
        "cluster": "2x8",
        "backend": "hostref"
    }"#;
    let spec = RunSpec::from_json(json).unwrap();
    assert_eq!(spec.cluster, ClusterSpec::dgx_2x8());
    assert_eq!(spec.backend, BackendSpec::HostRef);
    assert_eq!(spec.schedule, ScheduleKind::Balanced); // default
    assert_eq!(spec.layers, 1);
    assert!(spec.backward && !spec.trace);
    // and the parsed spec actually drives a session
    Session::new(spec).unwrap().plans().unwrap();
}

#[test]
fn shorthand_policies_and_backends_parse() {
    let json = r#"{
        "workload": {"n_heads": 2, "n_kv_heads": 1, "head_dim": 8, "chunk_tokens": 16},
        "n_workers": 4,
        "schedule": "ring",
        "backend": "null",
        "optimize": "schedule"
    }"#;
    let spec = RunSpec::from_json(json).unwrap();
    assert_eq!(spec.schedule, ScheduleKind::Ring);
    assert_eq!(spec.backend, BackendSpec::Null);
    assert_eq!(spec.optimize, OptimizePolicy::Schedule(OptimizeOpts::default()));
}

#[test]
fn malformed_specs_are_rejected_with_context() {
    // not JSON at all
    assert!(RunSpec::from_json("not json").is_err());
    // unknown backend string
    let err = RunSpec::from_json(
        r#"{"workload": {"n_heads": 2, "n_kv_heads": 1, "head_dim": 8, "chunk_tokens": 16},
            "n_workers": 4, "backend": "cuda"}"#,
    )
    .unwrap_err();
    assert!(format!("{err}").contains("backend"), "{err}");
    // unknown cluster preset
    assert!(RunSpec::from_json(
        r#"{"workload": {"n_heads": 2, "n_kv_heads": 1, "head_dim": 8, "chunk_tokens": 16},
            "n_workers": 4, "cluster": "9x9"}"#,
    )
    .is_err());
    // bad workload field type
    assert!(RunSpec::from_json(
        r#"{"workload": {"n_heads": "two", "n_kv_heads": 1, "head_dim": 8, "chunk_tokens": 16},
            "n_workers": 4}"#,
    )
    .is_err());
    // wrong-typed *optional* fields are errors too, never silent defaults
    assert!(RunSpec::from_json(
        r#"{"workload": {"n_heads": 2, "n_kv_heads": 1, "head_dim": 8, "chunk_tokens": 16},
            "n_workers": 4, "layers": "3"}"#,
    )
    .is_err());
    assert!(RunSpec::from_json(
        r#"{"workload": {"n_heads": 2, "n_kv_heads": 1, "head_dim": 8, "chunk_tokens": 16},
            "n_workers": 4, "optimize": {"schedule": {"swap_rounds": "20"}}}"#,
    )
    .is_err());
    // ckpt must be a known strategy name (case-insensitive) or null —
    // wrong types and unknown spellings are errors, never silent defaults
    let err = RunSpec::from_json(
        r#"{"workload": {"n_heads": 2, "n_kv_heads": 1, "head_dim": 8, "chunk_tokens": 16},
            "n_workers": 4, "ckpt": 3}"#,
    )
    .unwrap_err();
    assert!(format!("{err}").contains("ckpt"), "{err}");
    let err = RunSpec::from_json(
        r#"{"workload": {"n_heads": 2, "n_kv_heads": 1, "head_dim": 8, "chunk_tokens": 16},
            "n_workers": 4, "ckpt": "bogus"}"#,
    )
    .unwrap_err();
    assert!(format!("{err}").contains("remat-aware"), "must list spellings: {err}");
    // accepted spellings parse case-insensitively; omission = remat-aware
    for (text, want) in [
        (r#""HF-Style""#, CkptStrategy::HfStyle),
        (r#""ours""#, CkptStrategy::RematAware),
        ("null", CkptStrategy::RematAware),
    ] {
        let spec = RunSpec::from_json(&format!(
            r#"{{"workload": {{"n_heads": 2, "n_kv_heads": 1, "head_dim": 8, "chunk_tokens": 16}},
                "n_workers": 4, "ckpt": {text}}}"#,
        ))
        .unwrap();
        assert_eq!(spec.ckpt, want, "{text}");
    }

    // unknown recovery policy strings are rejected with the spellings
    let err = RunSpec::from_json(
        r#"{"workload": {"n_heads": 2, "n_kv_heads": 1, "head_dim": 8, "chunk_tokens": 16},
            "n_workers": 4, "recovery": "retry-forever"}"#,
    )
    .unwrap_err();
    assert!(format!("{err}").contains("fail_fast"), "must list spellings: {err}");
    // recovery knobs are type-checked, never silently defaulted
    assert!(RunSpec::from_json(
        r#"{"workload": {"n_heads": 2, "n_kv_heads": 1, "head_dim": 8, "chunk_tokens": 16},
            "n_workers": 4, "recovery": {"respawn": {"max_retries": "three"}}}"#,
    )
    .is_err());
    // omission keeps the PR 8 default: fail fast
    let spec = RunSpec::from_json(
        r#"{"workload": {"n_heads": 2, "n_kv_heads": 1, "head_dim": 8, "chunk_tokens": 16},
            "n_workers": 4}"#,
    )
    .unwrap();
    assert_eq!(spec.recovery, RecoveryPolicy::FailFast);

    // a parseable spec can still fail validation (varlen/worker mismatch)
    let spec = RunSpec::from_json(
        r#"{"workload": {"n_heads": 2, "n_kv_heads": 1, "head_dim": 8, "chunk_tokens": 16},
            "n_workers": 4,
            "varlen": {"doc_lens": [32], "boundaries": [0, 16, 32]}}"#,
    )
    .unwrap();
    assert!(Session::new(spec).is_err());
}
