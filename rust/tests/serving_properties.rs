//! Serving-subsystem acceptance suite (continuous-batching decode PR).
//!
//! * the paged KV-cache allocator never aliases slots across live
//!   requests, conserves pages after every operation, reuses evicted
//!   pages LIFO before never-used ones, and is deterministic under a
//!   fixed seed;
//! * the continuous-batching scheduler respects the admission token
//!   budget, the bounded waiting queue, and per-rank page capacity at
//!   every step;
//! * decode plans have *ragged* per-step op counts, and
//!   [`MergedTrace::step_counts`] / the executed trace's `ops_per_step`
//!   track the plan exactly — the regression pin for the old
//!   fixed-ops-per-pass trace-merging assumption;
//! * `serve` hits the acceptance bar: continuous batching >= 2x the
//!   serial baseline's tokens/sec, simulated **and** executed, with the
//!   event engine reproducing the scheduler's virtual clock to 1e-9;
//! * the decode kernel is bit-identical across thread counts and to the
//!   scalar oracle, and the executed trace records the effective
//!   threads + tile pick (autotuned or default);
//! * `ServeSpec` round-trips through JSON, including trace-replay
//!   arrival processes, which also execute end to end.

use std::collections::{BTreeMap, BTreeSet};

use distflash::baselines::attn_cost_from_dims;
use distflash::coordinator::MergedTrace;
use distflash::runtime::{kernel, HostKernels, Kernels, Tensor, Tiles, Value};
use distflash::serving::scheduler::{lower, schedule};
use distflash::serving::{
    gen_requests, rank_ops, serve, Arrivals, PagedKvCache, ServeLog, ServeSpec,
};
use distflash::simulator::AttnCost;
use distflash::util::Rng;

fn dev_cost(spec: &ServeSpec) -> AttnCost {
    let w = &spec.workload;
    attn_cost_from_dims(&spec.cluster, w.chunk_tokens as f64, w.n_heads, w.n_kv_heads, w.head_dim)
}

/// Every live slot assignment in the cache, flattened for comparison.
fn live_slots(cache: &PagedKvCache, live: &BTreeSet<usize>) -> Vec<(usize, Vec<usize>)> {
    live.iter().map(|&r| (r, cache.slots(r).unwrap())).collect()
}

#[test]
fn cache_conserves_pages_and_never_aliases() {
    let (kvh, d) = (2, 4);
    let row = kvh * d;
    // twin caches driven through the identical call sequence must agree
    // on every slot assignment (determinism under a fixed seed)
    let mut a = PagedKvCache::new(4, 10, kvh, d);
    let mut b = PagedKvCache::new(4, 10, kvh, d);
    let mut rng = Rng::new(0xc0ffee);
    let mut live: BTreeSet<usize> = BTreeSet::new();
    for op in 0..400 {
        let req = rng.below(8);
        let evict = live.contains(&req) && rng.below(3) == 0;
        if evict {
            assert_eq!(a.evict(req).unwrap(), b.evict(req).unwrap());
            live.remove(&req);
        } else {
            let tokens = 1 + rng.below(6);
            let k = rng.normal_vec(tokens * row);
            let v = rng.normal_vec(tokens * row);
            let ra = a.append(req, &k, &v);
            let rb = b.append(req, &k, &v);
            assert_eq!(ra.is_ok(), rb.is_ok(), "op {op}: twins diverged");
            if ra.is_ok() {
                live.insert(req);
            } else {
                // a failed append must not mutate anything
                assert_eq!(a.len(req), b.len(req));
            }
        }
        // conservation after every operation
        assert_eq!(a.free_pages() + a.used_pages(), a.n_pages(), "op {op}: pages leaked");
        // no slot aliasing across live requests
        let mut seen = BTreeSet::new();
        for (r, slots) in live_slots(&a, &live) {
            for s in slots {
                assert!(s < a.n_slots(), "req {r}: slot {s} out of range");
                assert!(seen.insert(s), "op {op}: slot {s} aliased (req {r})");
            }
        }
        // determinism: identical slot maps on the twin
        assert_eq!(live_slots(&a, &live), live_slots(&b, &live), "op {op}");
    }
}

#[test]
fn evicted_pages_are_reused_before_fresh_ones() {
    let (kvh, d) = (1, 1);
    let mut c = PagedKvCache::new(2, 6, kvh, d);
    // req 0 takes pages 0,1; req 1 takes page 2
    c.append(0, &[0.0; 4], &[0.0; 4]).unwrap();
    c.append(1, &[0.0; 2], &[0.0; 2]).unwrap();
    assert_eq!(c.slots(0).unwrap(), vec![0, 1, 2, 3]);
    assert_eq!(c.slots(1).unwrap(), vec![4, 5]);
    // evicting req 0 returns its pages in reverse allocation order, so
    // the next allocations reuse 0 then 1 — never the fresh page 3
    c.evict(0).unwrap();
    c.append(2, &[0.0; 2], &[0.0; 2]).unwrap();
    assert_eq!(c.slots(2).unwrap(), vec![0, 1], "most recently freed page reused first");
    c.append(3, &[0.0; 2], &[0.0; 2]).unwrap();
    assert_eq!(c.slots(3).unwrap(), vec![2, 3]);
    // only now does a fresh page get handed out
    c.append(4, &[0.0; 2], &[0.0; 2]).unwrap();
    assert_eq!(c.slots(4).unwrap(), vec![6, 7]);
}

/// Replay a step log, tracking the running set and per-request context,
/// and assert the scheduler's backpressure invariants at every step.
fn check_schedule_invariants(spec: &ServeSpec, log: &ServeLog) {
    let requests = gen_requests(spec);
    let p = spec.n_workers;
    let mut running: BTreeSet<usize> = BTreeSet::new();
    let mut ctx: BTreeMap<usize, usize> = BTreeMap::new();
    for (s, step) in log.steps.iter().enumerate() {
        for w in 0..p {
            for &r in &step.evict[w] {
                assert!(running.remove(&r), "step {s}: evicted {r} was not running");
                ctx.remove(&r);
            }
            for &r in &step.prefill[w] {
                assert!(running.insert(r), "step {s}: {r} prefilled twice");
                ctx.insert(r, requests[r].prompt);
                assert_eq!(log.home[r], w, "step {s}: {r} prefilled off its home rank");
            }
        }
        // admission reserves each request's full lifetime context
        let reserved: usize =
            running.iter().map(|&r| requests[r].prompt + requests[r].decode).sum();
        assert!(
            reserved <= spec.max_batch_tokens,
            "step {s}: {reserved} reserved tokens > budget {}",
            spec.max_batch_tokens
        );
        for w in 0..p {
            for &r in &step.decode[w] {
                assert!(running.contains(&r), "step {s}: decoding non-running {r}");
                *ctx.get_mut(&r).unwrap() += 1;
            }
            // resident pages never exceed the rank's capacity
            let used: usize = running
                .iter()
                .filter(|&&r| log.home[r] == w)
                .map(|&r| ctx[&r].div_ceil(spec.page_size))
                .sum();
            assert!(used <= spec.n_pages, "step {s} rank {w}: {used} pages > {}", spec.n_pages);
        }
    }
    assert!(running.is_empty(), "requests left running after the last step");
    assert!(
        log.peak_queue <= spec.queue_cap,
        "peak queue {} > cap {}",
        log.peak_queue,
        spec.queue_cap
    );
}

#[test]
fn scheduler_respects_budget_queue_cap_and_pages() {
    // the roomy dev preset and a deliberately tight variant: pages for
    // exactly one full request per rank, budget for two in flight, a
    // two-deep queue — backpressure actually binds here
    let tight = ServeSpec {
        n_pages: 3,
        max_batch_tokens: 36,
        queue_cap: 2,
        ..ServeSpec::dev()
    };
    for spec in [ServeSpec::dev(), tight] {
        spec.validate().unwrap();
        let requests = gen_requests(&spec);
        let log = schedule(&spec, &requests, &dev_cost(&spec)).unwrap();
        check_schedule_invariants(&spec, &log);
    }
}

#[test]
fn decode_plans_are_ragged_and_step_counts_track_the_plan() {
    let spec = ServeSpec::dev();
    let requests = gen_requests(&spec);
    let log = schedule(&spec, &requests, &dev_cost(&spec)).unwrap();
    let low = lower(&spec, requests.len(), &log);
    low.plan.validate().unwrap();
    let counts = MergedTrace::step_counts(&low.plan);
    assert_eq!(counts.len(), low.plan.n_steps);
    assert_eq!(counts.len(), log.steps.len());
    let c_ref = spec.workload.chunk_tokens as f64;
    for (s, step) in log.steps.iter().enumerate() {
        let expect: usize =
            (0..spec.n_workers).map(|w| rank_ops(step, w, c_ref).len()).sum();
        assert_eq!(counts[s], expect, "step {s}: plan op count drifted from the log");
    }
    // the regression this suite pins: decode plans shrink as requests
    // finish, so per-step op counts are NOT constant — any trace-merging
    // code assuming fixed ops-per-pass would misattribute spans here
    let (lo, hi) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
    assert!(lo < hi, "expected ragged per-step op counts, got a constant {lo}");
}

#[test]
fn continuous_batching_hits_the_2x_gate_simulated_and_executed() {
    let cont = serve(&ServeSpec::dev()).unwrap();
    let serial = serve(&ServeSpec { batching: false, ..ServeSpec::dev() }).unwrap();
    for out in [&cont, &serial] {
        // the event engine reproduces the scheduler's virtual clock
        let rel = (out.sim.total_s - out.log.total_s).abs() / out.log.total_s.max(1e-30);
        assert!(rel < 1e-9, "sim {} vs virtual clock {}", out.sim.total_s, out.log.total_s);
        assert!(out.sim.p50_latency_s <= out.sim.p99_latency_s);
        assert!(out.sim.p99_latency_s <= out.sim.total_s + 1e-12);
        // the executed leg oracle-checked every decode value (serve
        // fails on any mismatch) and covered the whole plan
        let ex = out.exec.as_ref().expect("hostref backend executes");
        assert!(ex.checked_values > 0 && ex.mismatched_values == 0);
        assert_eq!(ex.trace.ops_per_step, MergedTrace::step_counts(&out.lowered.plan));
        assert!(ex.trace.covered.iter().all(|&c| c), "uncovered plan ops in the replay");
        assert!(ex.calibration_rel_err.is_finite());
    }
    let sim_gain = cont.sim.tokens_per_s / serial.sim.tokens_per_s;
    assert!(sim_gain >= 2.0, "simulated batching gain {sim_gain:.2}x < 2x");
    let exec_gain = cont.exec.as_ref().unwrap().score.tokens_per_s
        / serial.exec.as_ref().unwrap().score.tokens_per_s;
    assert!(exec_gain >= 2.0, "executed batching gain {exec_gain:.2}x < 2x");
}

#[test]
fn decode_kernel_is_bit_identical_across_thread_counts() {
    let (h, kvh, d, b, n_slots) = (4, 2, 8, 3, 24);
    let mut rng = Rng::new(42);
    let q = rng.normal_vec(h * b * d);
    let k_slab = rng.normal_vec(n_slots * kvh * d);
    let v_slab = rng.normal_vec(n_slots * kvh * d);
    // three requests with ragged contexts over disjoint slot sets
    let lens = [5usize, 3, 7];
    let max_ctx = 7;
    let mut slots = vec![0.0f32; b * max_ctx];
    let mut next = 0usize;
    for (i, &l) in lens.iter().enumerate() {
        for j in 0..l {
            slots[i * max_ctx + j] = (next + j) as f32;
        }
        next += l;
    }
    let inputs = [
        Value::F32(Tensor::new(vec![h, b, d], q)),
        Value::F32(Tensor::new(vec![n_slots, kvh, d], k_slab)),
        Value::F32(Tensor::new(vec![n_slots, kvh, d], v_slab)),
        Value::F32(Tensor::new(vec![b, max_ctx], slots)),
        Value::F32(Tensor::new(vec![b], lens.map(|l| l as f32).to_vec())),
    ];
    // the tiled path is bit-identical at every thread count (each
    // (head, request) row reduces wholly inside one worker)
    let base = HostKernels::tiled(1).run("decode_attn", &inputs).unwrap();
    for threads in [2, 5, 8] {
        let got = HostKernels::tiled(threads).run("decode_attn", &inputs).unwrap();
        assert_eq!(got.len(), base.len());
        for (gi, (g, r)) in got.iter().zip(&base).enumerate() {
            assert_eq!(g.shape, r.shape);
            for (i, (a, b)) in g.data().iter().zip(r.data()).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "threads {threads}, output {gi}, value {i}: {a} vs {b}"
                );
            }
        }
    }
    // the scalar oracle uses a different (naive serial) rounding order,
    // so it agrees only numerically, not bitwise
    let oracle = HostKernels::scalar().run("decode_attn", &inputs).unwrap();
    for (g, r) in oracle.iter().zip(&base) {
        for (a, b) in g.data().iter().zip(r.data()) {
            assert!((a - b).abs() <= 1e-5 * b.abs().max(1.0), "scalar {a} vs tiled {b}");
        }
    }
}

#[test]
fn executed_trace_records_threads_and_tiles() {
    let tuned = serve(&ServeSpec { autotune_tiles: true, threads: 2, ..ServeSpec::dev() })
        .unwrap()
        .exec
        .unwrap();
    let pick = kernel::tiled::autotune();
    assert_eq!(tuned.trace.tiles, Some((pick.q, pick.k)), "autotuned pick not recorded");
    assert!(tuned.trace.threads >= 1 && tuned.trace.threads <= 2);
    let default = serve(&ServeSpec::dev()).unwrap().exec.unwrap();
    let t = Tiles::default();
    assert_eq!(default.trace.tiles, Some((t.q, t.k)), "default tiles not recorded");
    assert_eq!(default.trace.threads, 1);
}

#[test]
fn serve_spec_replay_round_trips_and_executes() {
    let spec = ServeSpec {
        arrivals: Arrivals::Replay { times_s: vec![0.0, 0.0, 1e-4, 1e-4, 2e-4, 5e-4] },
        n_requests: 6,
        threads: 2,
        seed: 1234567,
        ..ServeSpec::dev()
    };
    let parsed = ServeSpec::from_json(&spec.to_json()).unwrap();
    assert_eq!(parsed, spec);
    let out = serve(&parsed).unwrap();
    assert_eq!(out.requests.len(), 6);
    // replay arrivals land verbatim in the request stream
    for (r, t) in out.requests.iter().zip([0.0, 0.0, 1e-4, 1e-4, 2e-4, 5e-4]) {
        assert_eq!(r.arrival_s, t);
    }
    let ex = out.exec.expect("hostref backend executes");
    assert!(ex.checked_values > 0 && ex.mismatched_values == 0);
    check_schedule_invariants(&parsed, &out.log);
}
