//! `cargo bench --bench hot_paths` — L3 hot-path microbenchmarks with the
//! perf targets from DESIGN.md §9:
//!   * schedule build: < 1 ms at P=1024
//!   * schedule simulation: >= 1e6 slots/s
//!   * host flash kernels: tiled/vectorized >= 5x the scalar oracle at one
//!     thread (d=128 GQA geometry), and the worker pool must actually scale
//!   * ring all-reduce (4 threads, 4 MB): memory-bound, not lock-bound
//!   * tensor chunk/cat (the executor's shard/gather path)
//!   * JSON manifest parse
//!
//! Results feed EXPERIMENTS.md §Perf (before/after iteration log).

use distflash::config::ClusterSpec;
use distflash::coordinator::comm::build_network;
use distflash::coordinator::{
    optimize_schedule, optimize_varlen, OptimizeOpts, Pass, Plan, Schedule, VarlenSpec,
};
use distflash::runtime::Tensor;
use distflash::simulator::{simulate_attention, simulate_plan, AttnCost, EventOpts, PlanSim};
use distflash::util::bench::{bench, black_box};
use distflash::util::{Json, Rng};

fn main() {
    println!("== L3 hot paths ==");

    // schedule construction
    for p in [8usize, 64, 256, 1024] {
        let s = bench(&format!("schedule_balanced_build_p{p}"), 3, 30, || {
            black_box(Schedule::balanced(black_box(p)));
        });
        println!("{}", s.report());
        if p == 1024 {
            // perf log (EXPERIMENTS.md §Perf): 157 ms (Vec-based plans)
            // -> 41 ms (Option-based, allocation-free); the remaining cost
            // is the O(P²/2) plan matrix itself on this single-vCPU box.
            // Realistic schedules (P <= 64) build in <20 µs.
            assert!(
                s.mean_ms() < 60.0,
                "P=1024 schedule build regressed: {:.2} ms",
                s.mean_ms()
            );
        }
    }

    // schedule validation (runs at executor startup)
    let sched256 = Schedule::balanced(256);
    println!(
        "{}",
        bench("schedule_validate_p256", 3, 20, || {
            sched256.validate().unwrap();
        })
        .report()
    );

    // simulator throughput
    let cluster = ClusterSpec::dgx_2x8();
    let cost = AttnCost {
        pair_full_s: 1e-3,
        pair_diag_s: 5e-4,
        rescale_s: 1e-5,
        kv_bytes: 1e6,
        q_bytes: 5e5,
        result_bytes: 6e5,
        overlap: true,
    };
    for p in [16usize, 128, 512] {
        let sched = Schedule::balanced(p);
        let slots = (sched.n_steps() * p) as f64;
        let s = bench(&format!("simulate_attention_p{p}"), 3, 30, || {
            black_box(simulate_attention(&sched, &cluster, &cost));
        });
        println!(
            "{}   ({:.1}M slots/s)",
            s.report(),
            slots / s.mean_ns * 1e3
        );
    }

    // schedule-IR lowering + event-driven simulation throughput
    for p in [16usize, 128, 512] {
        let sched = Schedule::balanced(p);
        let s = bench(&format!("plan_lower_fwd_p{p}"), 3, 30, || {
            black_box(Plan::from_schedule(black_box(&sched), Pass::Forward));
        });
        println!("{}", s.report());
        let plan = Plan::from_schedule(&sched, Pass::Forward);
        let ops = plan.n_ops() as f64;
        let s = bench(&format!("simulate_plan_p{p}"), 3, 30, || {
            black_box(simulate_plan(&plan, &cluster, &cost, &EventOpts::default()));
        });
        println!("{}   ({:.1}M ops/s)", s.report(), ops / s.mean_ns * 1e3);
        // the optimizer's scoring path: pre-resolved costs, reused scratch
        let mut sim = PlanSim::new(&plan, &cost);
        let placement: Vec<usize> = (0..p).collect();
        let s = bench(&format!("plan_sim_reuse_p{p}"), 3, 50, || {
            black_box(sim.total_s(&cluster, &placement, 1));
        });
        println!("{}   ({:.1}M ops/s)", s.report(), ops / s.mean_ns * 1e3);
    }

    // end-to-end plan optimizer (flips + placement hill climb + depth
    // sweep) — the whole search must stay interactive: a few hundred
    // event-engine passes, well under the bench budget
    {
        let sched = Schedule::balanced(16);
        let s = bench("optimize_schedule_p16_2x8", 1, 5, || {
            black_box(optimize_schedule(
                &sched,
                Pass::Forward,
                &cluster,
                &cost,
                &OptimizeOpts::default(),
            ));
        });
        println!("{}", s.report());
        // generous wall-clock ceiling: the search is ~5 ms in release on
        // the reference box; only a pathological regression (e.g. an
        // accidentally quadratic rescore) trips this on any machine
        assert!(
            s.mean_ms() < 2000.0,
            "optimizer search blew its budget: {:.1} ms",
            s.mean_ms()
        );
    }

    // token-level varlen rebalancer: boundary moves + per-pair flips over
    // the dense dual plan, scored by the incremental rescorer — the
    // enlarged search must stay in the same sim-call budget order as the
    // PR 2 passes (a few hundred event-engine passes)
    {
        let spec = VarlenSpec::pack_zipf(64, 2048 * 16, 1.1, 17, 16);
        let sched = Schedule::balanced(16);
        let mut sim_calls = 0usize;
        let mut inc = 0usize;
        let s = bench("optimize_varlen_p16_2x8", 1, 5, || {
            let o = optimize_varlen(
                &sched,
                &spec,
                Pass::Forward,
                &cluster,
                &cost,
                &OptimizeOpts::default(),
            );
            sim_calls = o.sim_calls;
            inc = o.incremental_rescores;
            black_box(o.optimized_s);
        });
        println!("{}   ({sim_calls} sim calls, {inc} incremental)", s.report());
        assert!(
            sim_calls < 2500,
            "varlen search budget blown: {sim_calls} sim calls"
        );
        assert!(
            s.mean_ms() < 2000.0,
            "varlen rebalance blew its wall budget: {:.1} ms",
            s.mean_ms()
        );
    }

    // host flash kernels: the tiled/vectorized path vs the scalar oracle —
    // the kernel floor every measured trace stands on. Gate: >= 5x at a
    // single thread on the paper-scale d=128 GQA geometry, and real
    // scaling from the (head, q-tile) worker pool when the box has cores.
    {
        use distflash::runtime::{HostKernels, Kernels, Value};
        let (h, kvh, c, d) = (8usize, 2usize, 256usize, 128usize);
        let mut rng = Rng::new(11);
        let q = Tensor::new(vec![h, c, d], rng.normal_vec(h * c * d));
        let kt = Tensor::new(vec![kvh, c, d], rng.normal_vec(kvh * c * d));
        let v = Tensor::new(vec![kvh, c, d], rng.normal_vec(kvh * c * d));
        let do_ = Tensor::new(vec![h, c, d], rng.normal_vec(h * c * d));
        let o0 = Tensor::zeros(&[h, c, d]);
        let m0 = Tensor::new(vec![h, c], vec![f32::NEG_INFINITY; h * c]);
        let l0 = Tensor::zeros(&[h, c]);
        // a real forward's (o, lse) so the backward arm is representative
        let fwd_out = HostKernels::tiled(1)
            .run("full_attn_ref", &[q.clone().into(), kt.clone().into(), v.clone().into()])
            .unwrap();
        let fwd_inputs: Vec<Value> = vec![
            q.clone().into(),
            kt.clone().into(),
            v.clone().into(),
            o0.into(),
            m0.into(),
            l0.into(),
        ];
        let bwd_inputs: Vec<Value> = vec![
            q.into(),
            kt.into(),
            v.into(),
            fwd_out[0].clone().into(),
            fwd_out[1].clone().into(),
            do_.into(),
        ];
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        for (kernel, inputs) in [("attn_fwd_full", &fwd_inputs), ("attn_bwd_diag", &bwd_inputs)] {
            let scalar = bench(&format!("kernel_scalar_{kernel}"), 1, 3, || {
                black_box(HostKernels::scalar().run(kernel, inputs).unwrap());
            });
            println!("{}", scalar.report());
            let tiled = bench(&format!("kernel_tiled1_{kernel}"), 1, 3, || {
                black_box(HostKernels::tiled(1).run(kernel, inputs).unwrap());
            });
            let speedup = scalar.p50_ns / tiled.p50_ns;
            println!("{}   ({speedup:.1}x vs scalar)", tiled.report());
            assert!(
                speedup >= 5.0,
                "{kernel}: tiled single-thread only {speedup:.2}x over scalar (gate: 5x)"
            );
            if hw >= 4 {
                let mt = bench(&format!("kernel_tiled4_{kernel}"), 1, 3, || {
                    black_box(HostKernels::tiled(4).run(kernel, inputs).unwrap());
                });
                let mt_speedup = tiled.p50_ns / mt.p50_ns;
                println!("{}   ({mt_speedup:.1}x vs 1 thread)", mt.report());
                assert!(
                    mt_speedup >= 1.8,
                    "{kernel}: 4 threads only {mt_speedup:.2}x over 1 thread (gate: 1.8x)"
                );
            }
        }
    }

    // ring all-reduce over real threads (4 workers, 1M f32 each)
    let s = bench("ring_all_reduce_4x4MB", 1, 10, || {
        let comms = build_network(4);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                std::thread::spawn(move || {
                    let mut t = Tensor::full(&[1 << 20], c.rank as f32);
                    c.all_reduce_sum(1, &mut t).unwrap();
                    black_box(t.data()[0]);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
    println!("{}", s.report());

    // send-path allocation: the executor enqueues payloads via `clone()`.
    // Pre-PR that was a full 16 MB allocation + memcpy per tensor per
    // send; post-PR it is an Arc refcount bump. `deep_clone` preserves the
    // old behavior for comparison (and is what `deep_copy_sends` uses).
    let kv_chunk = Tensor::zeros(&[8, 4096, 128]);
    let s = bench("send_path_deep_clone_16MB", 2, 20, || {
        black_box(kv_chunk.deep_clone());
    });
    println!("{}", s.report());
    let deep_ns = s.mean_ns;
    let s = bench("send_path_arc_clone_16MB", 2, 20, || {
        black_box(kv_chunk.clone());
    });
    println!(
        "{}   ({:.0}x cheaper than deep clone)",
        s.report(),
        deep_ns / s.mean_ns.max(1.0)
    );

    // tensor shard/gather (executor chunking path)
    let mut rng = Rng::new(0);
    let big = Tensor::new(vec![32, 4096, 128], rng.normal_vec(32 * 4096 * 128));
    let s = bench("tensor_chunk_axis1_x8", 2, 20, || {
        black_box(big.chunk_axis1(8));
    });
    println!("{}", s.report());
    let parts = big.chunk_axis1(8);
    let s = bench("tensor_cat_axis1_x8", 2, 20, || {
        black_box(Tensor::cat_axis1(&parts));
    });
    println!("{}", s.report());

    // manifest JSON parse
    let manifest_path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny/manifest.json");
    if let Ok(text) = std::fs::read_to_string(manifest_path) {
        let s = bench("json_parse_manifest", 3, 50, || {
            black_box(Json::parse(&text).unwrap());
        });
        println!("{}", s.report());
    }

    println!("\nhot-path bench done (targets: DESIGN.md §9)");
}
