//! `cargo bench --bench paper_tables` — regenerates every table and figure
//! of the paper's evaluation and times each regeneration (criterion is
//! unavailable offline; the in-tree harness reports mean/p50/p95).
//!
//! The rendered tables are written to bench_tables_output.txt so the run
//! doubles as the reproduction record for EXPERIMENTS.md.

use distflash::report::paper;
use distflash::util::bench::bench;

fn main() {
    let jobs: Vec<(&str, fn() -> String)> = vec![
        ("table1_vs_megatron", paper::table1),
        ("table2_max_seq_fewer_heads", paper::table2),
        ("table3_vs_rsa", paper::table3),
        ("table4_vs_ulysses", paper::table4),
        ("table5_ckpt_ablation", paper::table5),
        ("table6_pp_memory", paper::table6),
        ("ring_attention_summary", paper::ring_attention_summary),
        ("executed_schedules", paper::executed_schedules),
        ("optimized_schedules", paper::optimized_schedules),
        ("fig1_idle_fraction", paper::fig1),
        ("fig2_timeline", paper::fig2),
        ("fig4_left_balance", paper::fig4_left),
        ("fig4_right_overlap", paper::fig4_right),
        ("fig7_time_breakdown", paper::fig7),
    ];

    let mut rendered = String::new();
    println!("== paper table/figure regeneration ==");
    for (name, f) in &jobs {
        let stats = bench(name, 1, 10, || {
            std::hint::black_box(f());
        });
        println!("{}", stats.report());
        rendered.push_str(&f());
        rendered.push('\n');
    }

    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/bench_tables_output.txt");
    std::fs::write(out, &rendered).expect("write bench output");
    println!("\nrendered tables -> {out}");
}
