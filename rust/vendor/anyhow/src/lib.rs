//! In-tree, API-compatible subset of the `anyhow` crate.
//!
//! The build environment is fully offline (no crates.io), so the error
//! handling substrate the codebase was written against is vendored here:
//! a context-chain error type, the `anyhow!` / `bail!` / `ensure!` macros,
//! and the `Context` extension trait for `Result` and `Option`.
//!
//! Only the surface this repository uses is implemented. Display prints the
//! outermost message; `{:#}` prints the full `outer: inner: root` chain,
//! matching upstream anyhow's behavior closely enough for log output.

use std::fmt;

/// A context-chain error. `chain[0]` is the outermost (most recent) message.
pub struct Error {
    chain: Vec<String>,
}

/// `anyhow::Result<T>` — the crate-wide fallible return type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The `outer -> root` context messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

/// Any std error converts via `?`, capturing its source chain. (Like
/// upstream anyhow, `Error` itself does not implement `std::error::Error`,
/// which is what makes this blanket impl coherent.)
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string (or any displayable expression).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Early-return with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: `{}`", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(anyhow!("root {}", 42))
    }

    #[test]
    fn context_chain_renders() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root 42");
        assert_eq!(e.root_cause(), "root 42");
    }

    #[test]
    fn std_errors_convert() {
        fn io() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/path/xyz")?;
            Ok(s)
        }
        let e = io().unwrap_err();
        assert!(!format!("{e}").is_empty());
    }

    #[test]
    fn ensure_and_bail() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "too big: {x}");
            if x == 5 {
                bail!("five");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "too big: 12");
        assert_eq!(format!("{}", f(5).unwrap_err()), "five");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }
}
