//! Stub of the `xla` crate (xla_extension 0.5.1 PJRT bindings).
//!
//! The build environment has no xla_extension toolchain, so this vendored
//! stub provides the exact API surface `runtime/{client,tensor}.rs` uses.
//! Host-side pieces ([`Literal`] construction/reshape/readback) are real
//! implementations so tensor round-trips work; device-side pieces
//! ([`PjRtClient::cpu`] onward) return a clear "runtime unavailable" error.
//! `Runtime::load` therefore fails fast with an actionable message, and
//! every artifact-dependent test/example skips gracefully — the schedule
//! IR, simulators, and analytic baselines never touch this crate's device
//! path.
//!
//! To run against real PJRT, replace this path dependency with the actual
//! xla-rs bindings; the call sites were written against that API.

use std::fmt;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str = "PJRT backend unavailable: built against the in-tree `xla` stub \
(no xla_extension toolchain in this environment). Schedule IR, simulators, and analytic \
baselines are unaffected; artifact-backed execution needs the real xla-rs bindings \
(swap the `xla` path dependency in Cargo.toml)";

fn unavailable<T>() -> Result<T> {
    Err(Error(UNAVAILABLE.to_string()))
}

/// Element types a [`Literal`] can hold (the repo only uses f32 and i32).
/// Public only because [`NativeType`] mentions it; not part of the API.
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }
}

/// Host element types accepted by literals and device buffers.
pub trait NativeType: Copy {
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            Data::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            Data::F32(_) => None,
        }
    }
}

/// Host-side literal: fully functional (construction, reshape, readback).
#[derive(Clone, Debug)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

impl Literal {
    pub fn scalar(v: f32) -> Literal {
        Literal { dims: vec![], data: Data::F32(vec![v]) }
    }

    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into dims {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| Error("literal dtype mismatch".to_string()))
    }

    /// Untuple a tuple literal — only device results are tuples here, and
    /// the stub cannot produce device results.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }
}

#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (device-side: stubbed).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client (device-side: stubbed; `cpu()` is the fail-fast point).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
        assert!(Literal::vec1(&[1.0f32]).reshape(&[3]).is_err());
    }

    #[test]
    fn device_paths_fail_cleanly() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(format!("{e}").contains("unavailable"));
        assert!(HloModuleProto::from_text_file("/tmp/x.hlo.txt").is_err());
    }
}
