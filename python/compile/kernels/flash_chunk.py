"""L1: Pallas blockwise FlashAttention *chunk* kernels for DISTFLASHATTN.

These are the `attn(q_p, k_r, v_r, o_p, s_p)` kernels of the paper
(Alg. 3 / Appendix A): a FlashAttention2-style blockwise kernel revised so
that

  1. the running statistics ``o`` (unnormalized output), ``m`` (row max) and
     ``l`` (row sum) are *accumulated from previous chunk computations*
     instead of initialized inside the kernel, and
  2. the caller finalizes ``o / l`` and the logsumexp ``L = m + log l`` only
     after the *last* chunk (the paper's ``last`` flag) — here done by the
     separate :func:`finalize` op so the kernel itself stays chunk-agnostic.

Hardware adaptation (paper kernel is CUDA/Triton; see DESIGN.md §6): the
(B_r x d) / (B_c x d) SRAM tiles become Pallas blocks; q blocks ride the
grid axis (one program per q block, BlockSpec index map), the kv blocks are
walked with an inner ``fori_loop`` so the (o, m, l) carry stays in
registers/VMEM for the whole pass.  Both matmuls use
``preferred_element_type=f32`` so on a real TPU they land on the MXU.
``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers to plain HLO that the rust runtime
runs byte-identically.

All kernels are single-head ``(C, D)``; the multi-head ``(H, C, D)`` wrappers
in ``__init__.py`` vmap over heads.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128

NEG_BIG = -1.0e30  # in-block mask value; never a fully-masked first block


def _pick_block(c: int, block: int) -> int:
    """Largest divisor of ``c`` that is <= block (power-of-two chunks)."""
    b = min(block, c)
    while c % b != 0:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _fwd_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_in_ref,
    m_in_ref,
    l_in_ref,
    o_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    block_q: int,
    block_k: int,
    kv_len: int,
    causal: bool,
):
    qi = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32) * scale  # (Bq, D)

    n_kv_blocks = kv_len // block_k
    if causal:
        # Bq == Bk is enforced by the wrapper; block j == qi is the diagonal
        # block, everything past it is fully masked and skipped entirely.
        upper = qi + 1
    else:
        upper = n_kv_blocks

    row_ids = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    def body(j, carry):
        o_acc, m_acc, l_acc = carry
        k_j = pl.load(k_ref, (pl.ds(j * block_k, block_k), slice(None)))
        v_j = pl.load(v_ref, (pl.ds(j * block_k, block_k), slice(None)))
        s = jax.lax.dot_general(
            q,
            k_j.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (Bq, Bk)
        if causal:
            col_ids = j * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = row_ids[:, None] >= col_ids[None, :]
            s = jnp.where(mask, s, NEG_BIG)
        m_new = jnp.maximum(m_acc, jnp.max(s, axis=1))
        # m_acc == -inf on the very first block of the very first chunk:
        # exp(-inf - finite) == 0, no NaN (the diagonal block is never fully
        # masked for any row, so m_new is always finite after step one).
        alpha = jnp.exp(m_acc - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_acc * alpha + jnp.sum(p, axis=1)
        o_new = o_acc * alpha[:, None] + jax.lax.dot_general(
            p,
            v_j.astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return o_new, m_new, l_new

    o0 = o_in_ref[...].astype(jnp.float32)
    m0 = m_in_ref[...].astype(jnp.float32)
    l0 = l_in_ref[...].astype(jnp.float32)
    o_acc, m_acc, l_acc = jax.lax.fori_loop(0, upper, body, (o0, m0, l0))
    o_ref[...] = o_acc
    m_ref[...] = m_acc
    l_ref[...] = l_acc


def chunk_fwd(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    o: jax.Array,
    m: jax.Array,
    l: jax.Array,
    *,
    causal: bool,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
):
    """One `attn(q_p, k_r, v_r, o, s)` step (single head).

    Args:
      q, k, v: ``(C, D)`` chunk tensors (q from the owner, k/v possibly
        fetched from a remote worker).
      o: ``(C, D)`` running *unnormalized* output.
      m, l: ``(C,)`` running row max / row sum statistics.
      causal: True for the diagonal chunk (r == p), False for earlier chunks.

    Returns:
      updated ``(o, m, l)``.
    """
    c, d = q.shape
    kv_len = k.shape[0]
    bq = _pick_block(c, block)
    bk = _pick_block(kv_len, block)
    if causal:
        if c != kv_len:
            raise ValueError("causal diagonal chunk requires q/kv same length")
        bq = bk = min(bq, bk)
    scale = 1.0 / math.sqrt(d)
    grid = (c // bq,)
    kernel = functools.partial(
        _fwd_kernel,
        scale=scale,
        block_q=bq,
        block_k=bk,
        kv_len=kv_len,
        causal=causal,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((kv_len, d), lambda i: (0, 0)),
            pl.BlockSpec((kv_len, d), lambda i: (0, 0)),
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, d), jnp.float32),
            jax.ShapeDtypeStruct((c,), jnp.float32),
            jax.ShapeDtypeStruct((c,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, o, m, l)


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------
#
# FlashAttention2 backward over one chunk pair (q_p vs k_r/v_r), split in two
# kernels so every output block is written by exactly one grid program (the
# TPU revisit rule): dq accumulates over kv blocks (grid = q blocks), dk/dv
# accumulate over q blocks (grid = kv blocks). ``delta = rowsum(do * o)`` is
# precomputed by the caller (FA2's D).


def _bwd_dq_kernel(
    q_ref,
    k_ref,
    v_ref,
    do_ref,
    lse_ref,
    delta_ref,
    dq_ref,
    *,
    scale: float,
    block_q: int,
    block_k: int,
    kv_len: int,
    causal: bool,
):
    qi = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32)
    do = do_ref[...].astype(jnp.float32)
    lse = lse_ref[...].astype(jnp.float32)
    delta = delta_ref[...].astype(jnp.float32)
    row_ids = qi * block_q + jax.lax.iota(jnp.int32, block_q)
    upper = qi + 1 if causal else kv_len // block_k

    def body(j, dq_acc):
        k_j = pl.load(k_ref, (pl.ds(j * block_k, block_k), slice(None)))
        v_j = pl.load(v_ref, (pl.ds(j * block_k, block_k), slice(None)))
        s = (
            jax.lax.dot_general(
                q, k_j, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
            )
            * scale
        )
        p = jnp.exp(s - lse[:, None])
        if causal:
            col_ids = j * block_k + jax.lax.iota(jnp.int32, block_k)
            p = jnp.where(row_ids[:, None] >= col_ids[None, :], p, 0.0)
        dp = jax.lax.dot_general(
            do, v_j, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        ds = p * (dp - delta[:, None])
        return dq_acc + jax.lax.dot_general(
            ds, k_j, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    dq = jax.lax.fori_loop(0, upper, body, jnp.zeros_like(q))
    dq_ref[...] = dq * scale


def _bwd_dkv_kernel(
    q_ref,
    k_ref,
    v_ref,
    do_ref,
    lse_ref,
    delta_ref,
    dk_ref,
    dv_ref,
    *,
    scale: float,
    block_q: int,
    block_k: int,
    q_len: int,
    causal: bool,
):
    kj = pl.program_id(0)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    col_ids = kj * block_k + jax.lax.iota(jnp.int32, block_k)
    n_q_blocks = q_len // block_q
    # causal: q blocks before the diagonal contribute nothing to this kv block
    lower = kj if causal else 0

    def body(i, carry):
        dk_acc, dv_acc = carry
        q_i = pl.load(q_ref, (pl.ds(i * block_q, block_q), slice(None)))
        do_i = pl.load(do_ref, (pl.ds(i * block_q, block_q), slice(None)))
        lse_i = pl.load(lse_ref, (pl.ds(i * block_q, block_q),))
        delta_i = pl.load(delta_ref, (pl.ds(i * block_q, block_q),))
        s = (
            jax.lax.dot_general(
                q_i, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
            )
            * scale
        )
        p = jnp.exp(s - lse_i[:, None])
        if causal:
            row_ids = i * block_q + jax.lax.iota(jnp.int32, block_q)
            p = jnp.where(row_ids[:, None] >= col_ids[None, :], p, 0.0)
        dv_acc = dv_acc + jax.lax.dot_general(
            p, do_i, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        dp = jax.lax.dot_general(
            do_i, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        ds = p * (dp - delta_i[:, None])
        dk_acc = dk_acc + jax.lax.dot_general(
            ds, q_i, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return dk_acc, dv_acc

    dk, dv = jax.lax.fori_loop(
        lower, n_q_blocks, body, (jnp.zeros_like(k), jnp.zeros_like(v))
    )
    dk_ref[...] = dk * scale
    dv_ref[...] = dv


def chunk_bwd(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    o: jax.Array,
    lse: jax.Array,
    do: jax.Array,
    *,
    causal: bool,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
):
    """Backward of one chunk pair without recomputing the attention forward.

    This is what makes the rematerialization-aware checkpointing (§3.3) pay
    off: given the *saved* final output ``o`` and logsumexp ``lse``, it
    reconstructs the probabilities p = exp(s - L) block-wise — no forward
    pass, no inter-worker forward communication.

    Args:
      q, do, o: ``(Cq, D)`` owner-side tensors; ``lse`` is ``(Cq,)``.
      k, v: ``(Ck, D)`` the (possibly remote) kv chunk.
      causal: True for the diagonal pair.

    Returns:
      ``(dq, dk, dv)`` partials: dq accumulates on the owner, dk/dv are sent
      back to the kv chunk's owner.
    """
    cq, d = q.shape
    ck = k.shape[0]
    bq = _pick_block(cq, block)
    bk = _pick_block(ck, block)
    if causal:
        if cq != ck:
            raise ValueError("causal diagonal chunk requires q/kv same length")
        bq = bk = min(bq, bk)
    scale = 1.0 / math.sqrt(d)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=1)

    dq = pl.pallas_call(
        functools.partial(
            _bwd_dq_kernel,
            scale=scale,
            block_q=bq,
            block_k=bk,
            kv_len=ck,
            causal=causal,
        ),
        grid=(cq // bq,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((ck, d), lambda i: (0, 0)),
            pl.BlockSpec((ck, d), lambda i: (0, 0)),
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cq, d), jnp.float32),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(
            _bwd_dkv_kernel,
            scale=scale,
            block_q=bq,
            block_k=bk,
            q_len=cq,
            causal=causal,
        ),
        grid=(ck // bk,),
        in_specs=[
            pl.BlockSpec((cq, d), lambda i: (0, 0)),
            pl.BlockSpec((bk, d), lambda i: (i, 0)),
            pl.BlockSpec((bk, d), lambda i: (i, 0)),
            pl.BlockSpec((cq, d), lambda i: (0, 0)),
            pl.BlockSpec((cq,), lambda i: (0,)),
            pl.BlockSpec((cq,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bk, d), lambda i: (i, 0)),
            pl.BlockSpec((bk, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ck, d), jnp.float32),
            jax.ShapeDtypeStruct((ck, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# merge / finalize (elementwise; jnp is already optimal here)
# ---------------------------------------------------------------------------


def rescale(o1, m1, l1, o2, m2, l2):
    """Paper's `rescale(·)`: merge two partial (o, m, l) accumulator triples.

    Used by the load-balanced schedule when a helper worker ships its partial
    attention result back to the owner (Alg. 2 line 11). Exactly the FA2
    two-block combine; safe when one side is still the (0, -inf, 0) init.
    """
    m = jnp.maximum(m1, m2)
    # exp(-inf - -inf) would be NaN; a (-inf) m side has zero weight anyway.
    a1 = jnp.where(jnp.isneginf(m1), 0.0, jnp.exp(m1 - m))
    a2 = jnp.where(jnp.isneginf(m2), 0.0, jnp.exp(m2 - m))
    o = o1 * a1[..., None] + o2 * a2[..., None]
    l = l1 * a1 + l2 * a2
    return o, m, l


def finalize(o, m, l):
    """The paper's `last=True` epilogue: normalize and emit logsumexp L."""
    o_norm = o / l[..., None]
    lse = m + jnp.log(l)
    return o_norm, lse


def init_state(c: int, d: int):
    """(o^0, m^0, l^0) of Alg. 1 line 1."""
    return (
        jnp.zeros((c, d), jnp.float32),
        jnp.full((c,), -jnp.inf, jnp.float32),
        jnp.zeros((c,), jnp.float32),
    )
