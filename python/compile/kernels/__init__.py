"""L1 kernel package: Pallas chunk FlashAttention + multi-head wrappers.

The single-head kernels live in :mod:`flash_chunk`; this module vmaps them
over the head axis so L2 (``compile.model``) and the AOT exporter work with
``(H, C, D)`` tensors — the layout the rust executor ships between workers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import flash_chunk, ref
from .flash_chunk import DEFAULT_BLOCK, finalize, init_state, rescale


def mha_chunk_fwd(q, k, v, o, m, l, *, causal: bool, block: int = DEFAULT_BLOCK):
    """Multi-head `attn(·)` step: all tensors (H, C, D) / (H, C)."""
    f = functools.partial(flash_chunk.chunk_fwd, causal=causal, block=block)
    return jax.vmap(f)(q, k, v, o, m, l)


def mha_chunk_bwd(q, k, v, o, lse, do, *, causal: bool, block: int = DEFAULT_BLOCK):
    """Multi-head chunk-pair backward: returns (dq, dk, dv), all (H, C, D)."""
    f = functools.partial(flash_chunk.chunk_bwd, causal=causal, block=block)
    return jax.vmap(f)(q, k, v, o, lse, do)


def mha_init_state(h: int, c: int, d: int):
    """(o^0, m^0, l^0) for H heads."""
    return (
        jnp.zeros((h, c, d), jnp.float32),
        jnp.full((h, c), -jnp.inf, jnp.float32),
        jnp.zeros((h, c), jnp.float32),
    )


__all__ = [
    "flash_chunk",
    "ref",
    "rescale",
    "finalize",
    "init_state",
    "mha_chunk_fwd",
    "mha_chunk_bwd",
    "mha_init_state",
    "DEFAULT_BLOCK",
]
