"""Pure-jnp oracles for the Pallas chunk kernels (no Pallas, no blocking).

Everything here is the "obvious" O(C^2)-memory math; the pytest suite
asserts the blockwise kernels in :mod:`flash_chunk` match these to float32
tolerance, and the full-sequence oracles are also AOT-exported so the rust
distributed executor can check its numerics end-to-end.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def chunk_fwd_ref(q, k, v, o, m, l, *, causal: bool):
    """Reference for `flash_chunk.chunk_fwd` (single head, (C, D))."""
    d = q.shape[-1]
    s = (q @ k.T) / math.sqrt(d)
    if causal:
        cq, ck = s.shape
        mask = jnp.arange(cq)[:, None] >= jnp.arange(ck)[None, :]
        s = jnp.where(mask, s, -jnp.inf)
    m_blk = jnp.max(s, axis=1)
    p = jnp.exp(s - m_blk[:, None])
    l_blk = jnp.sum(p, axis=1)
    o_blk = p @ v
    # merge (o, m, l) with the incoming accumulator
    m_new = jnp.maximum(m, m_blk)
    a_old = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_new))
    a_blk = jnp.exp(m_blk - m_new)
    o_new = o * a_old[:, None] + o_blk * a_blk[:, None]
    l_new = l * a_old + l_blk * a_blk
    return o_new, m_new, l_new


def chunk_bwd_ref(q, k, v, o, lse, do, *, causal: bool):
    """Reference for `flash_chunk.chunk_bwd` (single head)."""
    d = q.shape[-1]
    scale = 1.0 / math.sqrt(d)
    s = (q @ k.T) * scale
    p = jnp.exp(s - lse[:, None])
    if causal:
        cq, ck = s.shape
        mask = jnp.arange(cq)[:, None] >= jnp.arange(ck)[None, :]
        p = jnp.where(mask, p, 0.0)
    delta = jnp.sum(do * o, axis=1)
    dv = p.T @ do
    dp = do @ v.T
    ds = p * (dp - delta[:, None])
    dq = (ds @ k) * scale
    dk = (ds.T @ q) * scale
    return dq, dk, dv


def full_attention_ref(q, k, v, *, causal: bool = True):
    """Monolithic softmax attention over a whole sequence, (C, D) per head."""
    d = q.shape[-1]
    s = (q @ k.T) / math.sqrt(d)
    if causal:
        cq, ck = s.shape
        mask = jnp.arange(cq)[:, None] >= jnp.arange(ck)[None, :]
        s = jnp.where(mask, s, -jnp.inf)
    return jax.nn.softmax(s, axis=-1) @ v


def full_attention_lse_ref(q, k, v, *, causal: bool = True):
    """Full attention plus the per-row logsumexp (for backward checks)."""
    d = q.shape[-1]
    s = (q @ k.T) / math.sqrt(d)
    if causal:
        cq, ck = s.shape
        mask = jnp.arange(cq)[:, None] >= jnp.arange(ck)[None, :]
        s = jnp.where(mask, s, -jnp.inf)
    lse = jax.scipy.special.logsumexp(s, axis=-1)
    return jnp.exp(s - lse[:, None]) @ v, lse


def mha_full_attention_ref(q, k, v, *, causal: bool = True):
    """(H, C, D) multi-head wrapper of the monolithic oracle."""
    return jax.vmap(lambda a, b, c: full_attention_ref(a, b, c, causal=causal))(
        q, k, v
    )
