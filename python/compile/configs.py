"""Model + export configurations shared by the AOT pipeline and pytest.

The rust side never imports this; it reads the shapes back from
``artifacts/<name>/manifest.json``.
"""

from __future__ import annotations

import dataclasses
import json


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A LLaMA-style decoder configuration, sequence-parallel over P workers.

    Every worker owns one chunk of ``chunk_len`` tokens; the full sequence is
    ``n_workers * chunk_len`` tokens (batch size 1 — the sequence-parallel
    regime the paper targets).
    """

    name: str
    vocab: int
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    chunk_len: int
    n_workers: int
    block: int  # pallas kernel block size (B_r == B_c)
    export_ref_grads: bool = False  # export the full-model grad oracle (tests)

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def group_size(self) -> int:
        assert self.n_heads % self.n_kv_heads == 0
        return self.n_heads // self.n_kv_heads

    @property
    def seq_len(self) -> int:
        return self.chunk_len * self.n_workers

    def n_params(self) -> int:
        e, f, v = self.d_model, self.d_ff, self.vocab
        kv = self.n_kv_heads * self.head_dim
        per_layer = e + e * e + 2 * e * kv + e * e + e + 2 * e * f + f * e
        return self.n_layers * per_layer + e + 2 * v * e

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["head_dim"] = self.head_dim
        d["seq_len"] = self.seq_len
        d["n_params"] = self.n_params()
        return d


CONFIGS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        # test config: small enough that every pytest / cargo test is fast
        ModelConfig("tiny", 256, 2, 64, 4, 4, 128, 32, 4, 16, export_ref_grads=True),
        # GQA variant of tiny (2 kv heads shared by groups of 2 queries)
        ModelConfig("tiny-gqa", 256, 2, 64, 4, 2, 128, 32, 4, 16, export_ref_grads=True),
        # odd worker count (exercises the P-odd balanced schedule)
        ModelConfig("tiny-p3", 256, 2, 64, 4, 4, 128, 32, 3, 16, export_ref_grads=True),
        # ~26M params: the fast end-to-end training demo
        ModelConfig("train20m", 4096, 6, 384, 6, 6, 1024, 128, 4, 64),
        # ~112M params: the paper-scale end-to-end run (slower per step)
        ModelConfig("train100m", 8192, 12, 768, 12, 12, 2048, 128, 4, 128),
    ]
}


def get(name: str) -> ModelConfig:
    try:
        return CONFIGS[name]
    except KeyError:
        raise KeyError(f"unknown config {name!r}; have {sorted(CONFIGS)}") from None


if __name__ == "__main__":
    print(json.dumps({k: v.to_json() for k, v in CONFIGS.items()}, indent=2))
