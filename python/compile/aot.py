"""AOT exporter: lower every L2 function to HLO *text* + manifest.json.

HLO text — NOT ``lowered.compiler_ir("hlo").as_hlo_proto().serialize()`` —
is the interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the ``xla`` crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage (from python/):
    python -m compile.aot --config tiny --out ../artifacts
    python -m compile.aot --all --out ../artifacts

Each config gets ``artifacts/<name>/<artifact>.hlo.txt`` plus one
``manifest.json`` describing input/output shapes, the parameter-order
contract, and the model config — everything the rust runtime needs; rust
never imports python.
"""

from __future__ import annotations

import argparse
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import CONFIGS, ModelConfig, get


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dtype_name(d) -> str:
    return {"float32": "f32", "int32": "i32"}[jnp.dtype(d).name]


def _tensor_meta(name, spec):
    return {
        "name": name,
        "shape": list(spec.shape),
        "dtype": _dtype_name(spec.dtype),
    }


class Exporter:
    def __init__(self, cfg: ModelConfig, out_dir: str):
        self.cfg = cfg
        self.dir = os.path.join(out_dir, cfg.name)
        os.makedirs(self.dir, exist_ok=True)
        self.manifest = {
            "config": cfg.to_json(),
            "layer_params": [
                {"name": n, "shape": list(s)}
                for n, s in M.layer_param_shapes(cfg).items()
            ],
            "global_params": [
                {"name": n, "shape": list(s)}
                for n, s in M.global_param_shapes(cfg).items()
            ],
            "artifacts": {},
        }

    def export(self, name: str, fn, inputs: list[tuple[str, jax.ShapeDtypeStruct]]):
        """Lower ``fn(*specs)`` and record the artifact in the manifest."""
        specs = [s for _, s in inputs]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(self.dir, fname)
        with open(path, "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *specs)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        self.manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [_tensor_meta(n, s) for n, s in inputs],
            "outputs": [_tensor_meta(f"out{i}", s) for i, s in enumerate(outs)],
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        print(f"  {self.cfg.name}/{fname}  ({len(text)} chars)")

    def finish(self):
        with open(os.path.join(self.dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)


def export_config(cfg: ModelConfig, out_dir: str):
    ex = Exporter(cfg, out_dir)
    h, kvh = cfg.n_heads, cfg.n_kv_heads
    c, d, e, f, v, n = (
        cfg.chunk_len,
        cfg.head_dim,
        cfg.d_model,
        cfg.d_ff,
        cfg.vocab,
        cfg.seq_len,
    )

    q_s, kv_s = _spec((h, c, d)), _spec((kvh, c, d))
    o_s, st_s = _spec((h, c, d)), _spec((h, c))

    # --- attention kernels (L1 pallas inside) ---
    for causal, tag in ((True, "diag"), (False, "full")):
        ex.export(
            f"attn_fwd_{tag}",
            functools.partial(M.attn_fwd, cfg, causal=causal),
            [("q", q_s), ("k", kv_s), ("v", kv_s), ("o", o_s), ("m", st_s), ("l", st_s)],
        )
        ex.export(
            f"attn_bwd_{tag}",
            functools.partial(M.attn_bwd, cfg, causal=causal),
            [("q", q_s), ("k", kv_s), ("v", kv_s), ("o", o_s), ("lse", st_s), ("do", o_s)],
        )
    ex.export(
        "attn_rescale",
        M.attn_rescale,
        [("o1", o_s), ("m1", st_s), ("l1", st_s), ("o2", o_s), ("m2", st_s), ("l2", st_s)],
    )
    ex.export(
        "attn_finalize",
        M.attn_finalize,
        [("o", o_s), ("m", st_s), ("l", st_s)],
    )
    ex.export(
        "full_attn_ref",
        functools.partial(M.full_model_fwd_attn_ref, cfg),
        [("q", _spec((h, n, d))), ("k", _spec((kvh, n, d))), ("v", _spec((kvh, n, d)))],
    )

    # --- layer pieces ---
    x_s = _spec((c, e))
    p1 = [("ln1_g", _spec((e,))), ("wq", _spec((e, e))),
          ("wk", _spec((e, kvh * d))), ("wv", _spec((e, kvh * d)))]
    ex.export(
        "part1_fwd",
        functools.partial(M.part1_fwd, cfg),
        [("x", x_s)] + p1,
    )
    ex.export(
        "part1_bwd",
        functools.partial(M.part1_bwd, cfg),
        [("x", x_s)] + p1 + [("dq", q_s), ("dk", kv_s), ("dv", kv_s)],
    )
    p2 = [("wo", _spec((e, e))), ("ln2_g", _spec((e,))),
          ("w1", _spec((e, f))), ("w3", _spec((e, f))), ("w2", _spec((f, e)))]
    ex.export(
        "part2_fwd",
        functools.partial(M.part2_fwd, cfg),
        [("x", x_s), ("attn_o", o_s)] + p2,
    )
    ex.export(
        "part2_bwd",
        functools.partial(M.part2_bwd, cfg),
        [("x", x_s), ("attn_o", o_s)] + p2 + [("dy", x_s)],
    )

    # --- embedding / head ---
    ids_s = _spec((c,), jnp.int32)
    ex.export(
        "embed_fwd",
        functools.partial(M.embed_fwd, cfg),
        [("ids", ids_s), ("w_emb", _spec((v, e)))],
    )
    ex.export(
        "embed_bwd",
        functools.partial(M.embed_bwd, cfg),
        [("ids", ids_s), ("dx", x_s)],
    )
    hl = [("x", x_s), ("ln_f_g", _spec((e,))), ("w_head", _spec((v, e))),
          ("targets", ids_s), ("inv_total", _spec((), jnp.float32))]
    ex.export("head_loss_fwd", functools.partial(M.head_loss_fwd, cfg), hl)
    ex.export("head_loss_bwd", functools.partial(M.head_loss_bwd, cfg), hl)

    # --- end-to-end oracles (small configs only: grads output is huge) ---
    if cfg.export_ref_grads:
        flat_specs = []
        for i in range(cfg.n_layers):
            for pname, shape in M.layer_param_shapes(cfg).items():
                flat_specs.append((f"L{i}.{pname}", _spec(shape)))
        for pname, shape in M.global_param_shapes(cfg).items():
            flat_specs.append((pname, _spec(shape)))
        seq_ids = _spec((n,), jnp.int32)
        ex.export(
            "full_model_loss",
            functools.partial(M.full_model_loss_flat, cfg),
            [("ids", seq_ids), ("targets", seq_ids)] + flat_specs,
        )
        ex.export(
            "full_model_grads",
            functools.partial(M.full_model_grads_flat, cfg),
            [("ids", seq_ids), ("targets", seq_ids)] + flat_specs,
        )

    ex.finish()
    print(f"wrote {ex.dir}/manifest.json")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", action="append", default=[], help="config name(s)")
    ap.add_argument("--all", action="store_true", help="export every config")
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    names = sorted(CONFIGS) if args.all else (args.config or ["tiny", "tiny-gqa", "tiny-p3", "train20m"])
    for name in names:
        print(f"== exporting {name} ==")
        export_config(get(name), args.out)


if __name__ == "__main__":
    main()
