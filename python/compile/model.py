"""L2: the transformer compute graph, split at the boundaries DISTFLASHATTN needs.

A LLaMA-style decoder layer is exported in two pieces so the rust trainer can
place the *distributed* attention between them and implement both gradient
checkpointing strategies (paper §3.3):

    part1:  x ──RMSNorm──QKV proj──► (q, k, v)            [local, per chunk]
    (distributed DISTFLASHATTN forward happens in rust)
    part2:  (x, attn_o) ──Wo──+residual──RMSNorm──SwiGLU──+residual──► y

Backward pieces recompute their *own* cheap linear forward internally (that
recompute is exactly what both checkpointing strategies share); whether the
expensive distributed attention forward is recomputed is the strategy choice
and lives entirely in rust (`coordinator::checkpoint`).

All functions are pure with explicit parameter arrays so they AOT-export
cleanly; the parameter order contract with rust is `layer_param_names()` /
`global_param_names()` and is recorded in the manifest.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from . import kernels
from .kernels import ref as kref

# ---------------------------------------------------------------------------
# parameter contract
# ---------------------------------------------------------------------------

LAYER_PARAMS = ("ln1_g", "wq", "wk", "wv", "wo", "ln2_g", "w1", "w3", "w2")
GLOBAL_PARAMS = ("w_emb", "ln_f_g", "w_head")


def layer_param_shapes(cfg: ModelConfig) -> dict[str, tuple[int, ...]]:
    e, f = cfg.d_model, cfg.d_ff
    kv = cfg.n_kv_heads * cfg.head_dim
    return {
        "ln1_g": (e,),
        "wq": (e, e),
        "wk": (e, kv),
        "wv": (e, kv),
        "wo": (e, e),
        "ln2_g": (e,),
        "w1": (e, f),
        "w3": (e, f),
        "w2": (f, e),
    }


def global_param_shapes(cfg: ModelConfig) -> dict[str, tuple[int, ...]]:
    return {
        "w_emb": (cfg.vocab, cfg.d_model),
        "ln_f_g": (cfg.d_model,),
        "w_head": (cfg.vocab, cfg.d_model),
    }


def init_params(cfg: ModelConfig, seed: int = 0):
    """Scaled-gaussian init; returns (layers: list[dict], globals: dict)."""
    key = jax.random.PRNGKey(seed)
    layers = []
    for _ in range(cfg.n_layers):
        p = {}
        for name, shape in layer_param_shapes(cfg).items():
            key, sub = jax.random.split(key)
            if name.startswith("ln"):
                p[name] = jnp.ones(shape, jnp.float32)
            else:
                std = 0.02 if name != "w2" else 0.02 / jnp.sqrt(2.0 * cfg.n_layers)
                p[name] = jax.random.normal(sub, shape, jnp.float32) * std
        layers.append(p)
    g = {}
    for name, shape in global_param_shapes(cfg).items():
        key, sub = jax.random.split(key)
        if name == "ln_f_g":
            g[name] = jnp.ones(shape, jnp.float32)
        else:
            g[name] = jax.random.normal(sub, shape, jnp.float32) * 0.02
    return layers, g


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x, g, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def _split_heads(x, n_heads: int, head_dim: int):
    # (C, H*D) -> (H, C, D)
    c = x.shape[0]
    return x.reshape(c, n_heads, head_dim).transpose(1, 0, 2)


def _merge_heads(x):
    # (H, C, D) -> (C, H*D)
    h, c, d = x.shape
    return x.transpose(1, 0, 2).reshape(c, h * d)


def repeat_kv(k, group_size: int):
    """(KVH, C, D) -> (H, C, D) by repeating each kv head over its group."""
    if group_size == 1:
        return k
    return jnp.repeat(k, group_size, axis=0)


def group_kv_grads(dk, n_kv_heads: int):
    """(H, C, D) grads -> (KVH, C, D) by summing each query group."""
    h, c, d = dk.shape
    g = h // n_kv_heads
    if g == 1:
        return dk
    return dk.reshape(n_kv_heads, g, c, d).sum(axis=1)


# ---------------------------------------------------------------------------
# layer part 1: RMSNorm + QKV projection
# ---------------------------------------------------------------------------


def part1_fwd(cfg: ModelConfig, x, ln1_g, wq, wk, wv):
    """x (C, E) -> q (H, C, D), k, v (KVH, C, D)."""
    xn = rmsnorm(x, ln1_g)
    q = _split_heads(xn @ wq, cfg.n_heads, cfg.head_dim)
    k = _split_heads(xn @ wk, cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(xn @ wv, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


def part1_bwd(cfg: ModelConfig, x, ln1_g, wq, wk, wv, dq, dk, dv):
    """Recomputes part1 internally (cheap); returns (dx, dln1_g, dwq, dwk, dwv)."""

    def f(x, ln1_g, wq, wk, wv):
        return part1_fwd(cfg, x, ln1_g, wq, wk, wv)

    _, vjp = jax.vjp(f, x, ln1_g, wq, wk, wv)
    return vjp((dq, dk, dv))


# ---------------------------------------------------------------------------
# layer part 2: output projection + residual + RMSNorm + SwiGLU + residual
# ---------------------------------------------------------------------------


def part2_fwd(cfg: ModelConfig, x, attn_o, wo, ln2_g, w1, w3, w2):
    """(x (C, E), attn_o (H, C, D)) -> y (C, E)."""
    h = x + _merge_heads(attn_o) @ wo
    hn = rmsnorm(h, ln2_g)
    y = h + (jax.nn.silu(hn @ w1) * (hn @ w3)) @ w2
    return y


def part2_bwd(cfg: ModelConfig, x, attn_o, wo, ln2_g, w1, w3, w2, dy):
    """Returns (dx, d_attn_o, dwo, dln2_g, dw1, dw3, dw2)."""

    def f(x, attn_o, wo, ln2_g, w1, w3, w2):
        return part2_fwd(cfg, x, attn_o, wo, ln2_g, w1, w3, w2)

    _, vjp = jax.vjp(f, x, attn_o, wo, ln2_g, w1, w3, w2)
    return vjp(dy)


# ---------------------------------------------------------------------------
# embedding / head + loss
# ---------------------------------------------------------------------------


def embed_fwd(cfg: ModelConfig, ids, w_emb):
    """ids (C,) i32 -> x (C, E)."""
    return jnp.take(w_emb, ids, axis=0)


def embed_bwd(cfg: ModelConfig, ids, dx):
    """Scatter-add gradient into the embedding table."""
    dw = jnp.zeros((cfg.vocab, cfg.d_model), jnp.float32)
    return dw.at[ids].add(dx)


def head_loss_fwd(cfg: ModelConfig, x, ln_f_g, w_head, targets, inv_total):
    """Final RMSNorm + LM head + mean token cross-entropy.

    ``inv_total`` is 1/global_token_count so that summing the per-worker
    scalars (rust ring all-reduce) yields the global mean loss.
    """
    xn = rmsnorm(x, ln_f_g)
    logits = xn @ w_head.T  # (C, V)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    return jnp.sum(lse - gold) * inv_total


def head_loss_bwd(cfg: ModelConfig, x, ln_f_g, w_head, targets, inv_total):
    """Returns (loss, dx, dln_f_g, dw_head)."""

    def f(x, ln_f_g, w_head):
        return head_loss_fwd(cfg, x, ln_f_g, w_head, targets, inv_total)

    loss, vjp = jax.vjp(f, x, ln_f_g, w_head)
    dx, dg, dw = vjp(jnp.float32(1.0))
    return loss, dx, dg, dw


# ---------------------------------------------------------------------------
# attention artifact wrappers (call the L1 pallas kernels)
# ---------------------------------------------------------------------------


def attn_fwd(cfg: ModelConfig, q, k, v, o, m, l, *, causal: bool):
    """One distributed-attention step: q (H,C,D), k/v (KVH,C,D), state (H,·)."""
    kf = repeat_kv(k, cfg.group_size)
    vf = repeat_kv(v, cfg.group_size)
    return kernels.mha_chunk_fwd(q, kf, vf, o, m, l, causal=causal, block=cfg.block)


def attn_bwd(cfg: ModelConfig, q, k, v, o, lse, do, *, causal: bool):
    """Chunk-pair backward; dk/dv are re-grouped to (KVH, C, D)."""
    kf = repeat_kv(k, cfg.group_size)
    vf = repeat_kv(v, cfg.group_size)
    dq, dk, dv = kernels.mha_chunk_bwd(
        q, kf, vf, o, lse, do, causal=causal, block=cfg.block
    )
    return dq, group_kv_grads(dk, cfg.n_kv_heads), group_kv_grads(dv, cfg.n_kv_heads)


def attn_rescale(o1, m1, l1, o2, m2, l2):
    return kernels.rescale(o1, m1, l1, o2, m2, l2)


def attn_finalize(o, m, l):
    return kernels.finalize(o, m, l)


# ---------------------------------------------------------------------------
# monolithic reference model (oracle for the rust distributed trainer)
# ---------------------------------------------------------------------------


def _layer_full(cfg: ModelConfig, x, p):
    """One decoder layer over the FULL sequence with monolithic attention."""
    q, k, v = part1_fwd(cfg, x, p["ln1_g"], p["wq"], p["wk"], p["wv"])
    kf = repeat_kv(k, cfg.group_size)
    vf = repeat_kv(v, cfg.group_size)
    attn_o = kref.mha_full_attention_ref(q, kf, vf, causal=True)
    return part2_fwd(cfg, x, attn_o, p["wo"], p["ln2_g"], p["w1"], p["w3"], p["w2"])


def full_model_loss(cfg: ModelConfig, ids, targets, layers, glob):
    """Whole-sequence loss with naive attention — the numerics oracle."""
    x = embed_fwd(cfg, ids, glob["w_emb"])
    for p in layers:
        x = _layer_full(cfg, x, p)
    inv_total = jnp.float32(1.0 / ids.shape[0])
    return head_loss_fwd(cfg, x, glob["ln_f_g"], glob["w_head"], targets, inv_total)


def full_model_fwd_attn_ref(cfg: ModelConfig, q, k, v):
    """Monolithic full-sequence attention + lse, (H, N, D) in, used by the
    rust executor's `verify` to check the distributed forward."""
    kf = repeat_kv(k, cfg.group_size)
    vf = repeat_kv(v, cfg.group_size)

    def one(qh, kh, vh):
        return kref.full_attention_lse_ref(qh, kh, vh, causal=True)

    o, lse = jax.vmap(one)(q, kf, vf)
    return o, lse


def flatten_params(layers, glob):
    """Deterministic flat list matching the manifest's parameter table."""
    out = []
    for p in layers:
        out.extend(p[name] for name in LAYER_PARAMS)
    out.extend(glob[name] for name in GLOBAL_PARAMS)
    return out


def unflatten_params(cfg: ModelConfig, flat):
    n = len(LAYER_PARAMS)
    layers = []
    for i in range(cfg.n_layers):
        layers.append(dict(zip(LAYER_PARAMS, flat[i * n : (i + 1) * n])))
    glob = dict(zip(GLOBAL_PARAMS, flat[cfg.n_layers * n :]))
    return layers, glob


def full_model_loss_flat(cfg: ModelConfig, ids, targets, *flat):
    layers, glob = unflatten_params(cfg, list(flat))
    return full_model_loss(cfg, ids, targets, layers, glob)


def full_model_grads_flat(cfg: ModelConfig, ids, targets, *flat):
    """(loss, *grads) — the end-to-end gradient oracle for small configs."""
    loss, grads = jax.value_and_grad(
        lambda f: full_model_loss_flat(cfg, ids, targets, *f)
    )(list(flat))
    return (loss, *grads)
