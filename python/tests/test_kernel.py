"""L1 kernel correctness: Pallas chunk kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/blocks; fixed-seed numpy provides the data. These
are the CORE correctness signal for the whole stack — the rust executor
trusts exactly this math.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import flash_chunk as fc
from compile.kernels import ref
from compile.kernels import mha_chunk_bwd, mha_chunk_fwd, mha_init_state

RTOL, ATOL = 2e-4, 2e-5


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    c=st.sampled_from([16, 32, 64, 128]),
    d=st.sampled_from([8, 16, 32, 64]),
    block=st.sampled_from([8, 16, 32, 128]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_chunk_fwd_matches_ref(c, d, block, causal, seed):
    rng = np.random.default_rng(seed)
    q, k, v = rand(rng, c, d), rand(rng, c, d), rand(rng, c, d)
    o0, m0, l0 = fc.init_state(c, d)
    got = fc.chunk_fwd(q, k, v, o0, m0, l0, causal=causal, block=block)
    want = ref.chunk_fwd_ref(q, k, v, o0, m0, l0, causal=causal)
    for g, w in zip(got, want):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=RTOL, atol=ATOL)


@settings(max_examples=10, deadline=None)
@given(
    c=st.sampled_from([16, 64]),
    d=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_chunk_fwd_accumulates_from_prior_state(c, d, seed):
    """The kernel must continue from an arbitrary prior (o, m, l), not init."""
    rng = np.random.default_rng(seed)
    q = rand(rng, c, d)
    k1, v1, k2, v2 = (rand(rng, c, d) for _ in range(4))
    o0, m0, l0 = fc.init_state(c, d)
    s1 = fc.chunk_fwd(q, k1, v1, o0, m0, l0, causal=False, block=16)
    got = fc.chunk_fwd(q, k2, v2, *s1, causal=False, block=16)
    r1 = ref.chunk_fwd_ref(q, k1, v1, o0, m0, l0, causal=False)
    want = ref.chunk_fwd_ref(q, k2, v2, *r1, causal=False)
    for g, w in zip(got, want):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=RTOL, atol=ATOL)


@settings(max_examples=10, deadline=None)
@given(
    p=st.sampled_from([1, 2, 3, 4, 8]),
    c=st.sampled_from([16, 32]),
    d=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_multi_chunk_equals_full_attention(p, c, d, seed):
    """Alg. 1: iterating chunks r<=p + finalize == monolithic causal attn."""
    rng = np.random.default_rng(seed)
    n = c * p
    q, k, v = rand(rng, n, d), rand(rng, n, d), rand(rng, n, d)
    full, lse_full = ref.full_attention_lse_ref(q, k, v)
    for wp in range(p):
        sl = slice(wp * c, (wp + 1) * c)
        o, m, l = fc.init_state(c, d)
        o, m, l = fc.chunk_fwd(q[sl], k[sl], v[sl], o, m, l, causal=True, block=16)
        for r in range(wp):
            slr = slice(r * c, (r + 1) * c)
            o, m, l = fc.chunk_fwd(
                q[sl], k[slr], v[slr], o, m, l, causal=False, block=16
            )
        onorm, lse = fc.finalize(o, m, l)
        assert_allclose(np.asarray(onorm), np.asarray(full[sl]), rtol=RTOL, atol=ATOL)
        assert_allclose(np.asarray(lse), np.asarray(lse_full[sl]), rtol=RTOL, atol=ATOL)


@settings(max_examples=10, deadline=None)
@given(
    c=st.sampled_from([16, 32, 64]),
    d=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rescale_matches_sequential(c, d, seed):
    """Helper-merge (Alg. 2 rescale) == computing the chunks sequentially."""
    rng = np.random.default_rng(seed)
    q = rand(rng, c, d)
    k1, v1, k2, v2 = (rand(rng, c, d) for _ in range(4))
    o0, m0, l0 = fc.init_state(c, d)
    owner = fc.chunk_fwd(q, k1, v1, o0, m0, l0, causal=True, block=16)
    helper = fc.chunk_fwd(q, k2, v2, *fc.init_state(c, d), causal=False, block=16)
    merged = fc.rescale(*owner, *helper)
    seq = fc.chunk_fwd(q, k2, v2, *owner, causal=False, block=16)
    for g, w in zip(merged, seq):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=RTOL, atol=ATOL)


def test_rescale_with_empty_side_is_identity():
    rng = np.random.default_rng(0)
    c, d = 32, 16
    q, k, v = rand(rng, c, d), rand(rng, c, d), rand(rng, c, d)
    s = fc.chunk_fwd(q, k, v, *fc.init_state(c, d), causal=True, block=16)
    merged = fc.rescale(*s, *fc.init_state(c, d))
    for g, w in zip(merged, s):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=RTOL, atol=ATOL)
    assert not np.any(np.isnan(np.asarray(merged[0])))


def test_rescale_both_empty_no_nan():
    a = fc.rescale(*fc.init_state(8, 4), *fc.init_state(8, 4))
    assert not np.any(np.isnan(np.asarray(a[0])))


# --------------------------------------------------------------------------
# backward
# --------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    c=st.sampled_from([16, 32, 64]),
    d=st.sampled_from([16, 32]),
    block=st.sampled_from([8, 16, 64]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_chunk_bwd_matches_ref(c, d, block, causal, seed):
    rng = np.random.default_rng(seed)
    q, k, v, do = (rand(rng, c, d) for _ in range(4))
    o, lse = ref.full_attention_lse_ref(q, k, v, causal=causal)
    got = fc.chunk_bwd(q, k, v, o, lse, do, causal=causal, block=block)
    want = ref.chunk_bwd_ref(q, k, v, o, lse, do, causal=causal)
    for g, w in zip(got, want):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=5e-4, atol=5e-5)


@settings(max_examples=6, deadline=None)
@given(
    p=st.sampled_from([2, 3, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_chunked_bwd_equals_autodiff(p, seed):
    """Sum of chunk-pair (dq, dk, dv) partials == jax.grad of the oracle."""
    rng = np.random.default_rng(seed)
    c, d = 16, 16
    n = c * p
    q, k, v, do = (rand(rng, n, d) for _ in range(4))
    ofull, lsef = ref.full_attention_lse_ref(q, k, v)

    def loss(q, k, v):
        return jnp.sum(ref.full_attention_ref(q, k, v, causal=True) * do)

    dq_r, dk_r, dv_r = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    dq = np.zeros((n, d), np.float32)
    dk = np.zeros((n, d), np.float32)
    dv = np.zeros((n, d), np.float32)
    for wp in range(p):
        sl = slice(wp * c, (wp + 1) * c)
        for r in range(wp + 1):
            slr = slice(r * c, (r + 1) * c)
            dqp, dkr, dvr = fc.chunk_bwd(
                q[sl], k[slr], v[slr], ofull[sl], lsef[sl], do[sl],
                causal=(r == wp), block=8,
            )
            dq[sl] += np.asarray(dqp)
            dk[slr] += np.asarray(dkr)
            dv[slr] += np.asarray(dvr)
    assert_allclose(dq, np.asarray(dq_r), rtol=1e-3, atol=1e-4)
    assert_allclose(dk, np.asarray(dk_r), rtol=1e-3, atol=1e-4)
    assert_allclose(dv, np.asarray(dv_r), rtol=1e-3, atol=1e-4)


# --------------------------------------------------------------------------
# multi-head wrappers & edge cases
# --------------------------------------------------------------------------


@pytest.mark.parametrize("h", [1, 3, 4])
def test_mha_wrappers(h):
    rng = np.random.default_rng(1)
    c, d = 32, 16
    q, k, v = (rand(rng, h, c, d) for _ in range(3))
    o, m, l = mha_init_state(h, c, d)
    o, m, l = mha_chunk_fwd(q, k, v, o, m, l, causal=True, block=16)
    for i in range(h):
        w = ref.chunk_fwd_ref(q[i], k[i], v[i], *fc.init_state(c, d), causal=True)
        assert_allclose(np.asarray(o[i]), np.asarray(w[0]), rtol=RTOL, atol=ATOL)
    onorm = o / l[..., None]
    lse = jnp.asarray(m + np.log(np.asarray(l)))
    do = rand(rng, h, c, d)
    dq, dk, dv = mha_chunk_bwd(q, k, v, onorm, lse, do, causal=True, block=16)
    for i in range(h):
        w = ref.chunk_bwd_ref(q[i], k[i], v[i], onorm[i], lse[i], do[i], causal=True)
        assert_allclose(np.asarray(dq[i]), np.asarray(w[0]), rtol=5e-4, atol=5e-5)
        assert_allclose(np.asarray(dk[i]), np.asarray(w[1]), rtol=5e-4, atol=5e-5)
        assert_allclose(np.asarray(dv[i]), np.asarray(w[2]), rtol=5e-4, atol=5e-5)


def test_block_bigger_than_chunk_clamps():
    rng = np.random.default_rng(2)
    c, d = 16, 8
    q, k, v = (rand(rng, c, d) for _ in range(3))
    got = fc.chunk_fwd(q, k, v, *fc.init_state(c, d), causal=True, block=4096)
    want = ref.chunk_fwd_ref(q, k, v, *fc.init_state(c, d), causal=True)
    assert_allclose(np.asarray(got[0]), np.asarray(want[0]), rtol=RTOL, atol=ATOL)


def test_causal_requires_square():
    rng = np.random.default_rng(3)
    q = rand(rng, 16, 8)
    k = rand(rng, 32, 8)
    with pytest.raises(ValueError):
        fc.chunk_fwd(q, k, k, *fc.init_state(16, 8), causal=True)


def test_large_scores_numerically_stable():
    """Online softmax must survive logits far outside exp() range."""
    rng = np.random.default_rng(4)
    c, d = 32, 16
    q = rand(rng, c, d) * 100.0
    k = rand(rng, c, d) * 100.0
    v = rand(rng, c, d)
    o, m, l = fc.chunk_fwd(q, k, v, *fc.init_state(c, d), causal=True, block=16)
    onorm, lse = fc.finalize(o, m, l)
    assert not np.any(np.isnan(np.asarray(onorm)))
    want = ref.full_attention_ref(q, k, v, causal=True)
    assert_allclose(np.asarray(onorm), np.asarray(want), rtol=1e-3, atol=1e-4)


def test_pick_block():
    assert fc._pick_block(128, 128) == 128
    assert fc._pick_block(96, 64) == 48
    assert fc._pick_block(8, 128) == 8
    assert fc._pick_block(7, 4) == 1
