"""AOT pipeline checks: the exported HLO text + manifest must uphold the
contract the rust runtime depends on (shapes, ordering, dtype names), and
the HLO must be plain text parseable by xla_extension 0.5.1 (no serialized
protos — see aot.py docstring)."""

import json
import os

import pytest

from compile import aot, model as M
from compile.configs import get

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "tiny")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts/tiny missing — run `make artifacts`")
    with open(path) as f:
        return json.load(f)


def test_manifest_config_roundtrip(manifest):
    cfg = get("tiny")
    c = manifest["config"]
    assert c["n_workers"] == cfg.n_workers
    assert c["head_dim"] == cfg.head_dim
    assert c["seq_len"] == cfg.chunk_len * cfg.n_workers
    assert c["n_params"] == cfg.n_params()


def test_param_order_contract(manifest):
    names = [p["name"] for p in manifest["layer_params"]]
    assert names == list(M.LAYER_PARAMS)
    gnames = [p["name"] for p in manifest["global_params"]]
    assert gnames == list(M.GLOBAL_PARAMS)


def test_all_artifacts_present_and_text(manifest):
    required = {
        "attn_fwd_diag", "attn_fwd_full", "attn_bwd_diag", "attn_bwd_full",
        "attn_rescale", "attn_finalize", "full_attn_ref",
        "part1_fwd", "part1_bwd", "part2_fwd", "part2_bwd",
        "embed_fwd", "embed_bwd", "head_loss_fwd", "head_loss_bwd",
        "full_model_loss", "full_model_grads",
    }
    assert required <= set(manifest["artifacts"])
    for name, a in manifest["artifacts"].items():
        path = os.path.join(ART, a["file"])
        assert os.path.exists(path), name
        head = open(path).read(200)
        # HLO text, not binary proto
        assert "HloModule" in head, f"{name} is not HLO text"
        assert a["inputs"] and a["outputs"], name


def test_attn_artifact_shapes(manifest):
    cfg = get("tiny")
    a = manifest["artifacts"]["attn_fwd_diag"]
    h, c, d = cfg.n_heads, cfg.chunk_len, cfg.head_dim
    shapes = {i["name"]: i["shape"] for i in a["inputs"]}
    assert shapes["q"] == [h, c, d]
    assert shapes["k"] == [cfg.n_kv_heads, c, d]
    assert shapes["m"] == [h, c]
    assert [o["shape"] for o in a["outputs"]] == [[h, c, d], [h, c], [h, c]]


def test_dtypes_are_known(manifest):
    for a in manifest["artifacts"].values():
        for t in a["inputs"] + a["outputs"]:
            assert t["dtype"] in ("f32", "i32")


def test_hlo_text_helper_matches_gen(tmp_path):
    """to_hlo_text must produce xla-parsable text for a fresh lowering."""
    import jax
    import jax.numpy as jnp

    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "f32[4]" in text
