"""L2 model-piece correctness: the split layer parts compose to the oracle,
and every exported backward matches jax autodiff of the composed function.

This is exactly the contract the rust trainer relies on: it never sees the
composed layer, only part1/attn/part2 pieces plus their backward artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M
from compile.configs import get
from compile.kernels import flash_chunk as fc
from compile.kernels import ref as kref

RTOL, ATOL = 5e-4, 5e-5


@pytest.fixture(scope="module", params=["tiny", "tiny-gqa"])
def cfg(request):
    return get(request.param)


def rand_params(cfg, seed=0):
    return M.init_params(cfg, seed)


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


def test_param_shapes_consistent(cfg):
    layers, glob = rand_params(cfg)
    for name, shape in M.layer_param_shapes(cfg).items():
        assert layers[0][name].shape == shape
    for name, shape in M.global_param_shapes(cfg).items():
        assert glob[name].shape == shape
    flat = M.flatten_params(layers, glob)
    l2, g2 = M.unflatten_params(cfg, flat)
    assert all((l2[i][n] == layers[i][n]).all() for i in range(cfg.n_layers) for n in M.LAYER_PARAMS)
    assert (g2["w_head"] == glob["w_head"]).all()


def test_n_params_matches_actual(cfg):
    layers, glob = rand_params(cfg)
    total = sum(int(np.prod(p.shape)) for p in M.flatten_params(layers, glob))
    assert total == cfg.n_params()


def test_part1_bwd_matches_autodiff(cfg):
    rng = np.random.default_rng(0)
    x = rand(rng, cfg.chunk_len, cfg.d_model)
    layers, _ = rand_params(cfg)
    p = layers[0]
    args = (x, p["ln1_g"], p["wq"], p["wk"], p["wv"])
    dq = rand(rng, cfg.n_heads, cfg.chunk_len, cfg.head_dim)
    dk = rand(rng, cfg.n_kv_heads, cfg.chunk_len, cfg.head_dim)
    dv = rand(rng, cfg.n_kv_heads, cfg.chunk_len, cfg.head_dim)

    def scalar(*a):
        q, k, v = M.part1_fwd(cfg, *a)
        return jnp.sum(q * dq) + jnp.sum(k * dk) + jnp.sum(v * dv)

    want = jax.grad(scalar, argnums=tuple(range(5)))(*args)
    got = M.part1_bwd(cfg, *args, dq, dk, dv)
    for g, w in zip(got, want):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=RTOL, atol=ATOL)


def test_part2_bwd_matches_autodiff(cfg):
    rng = np.random.default_rng(1)
    x = rand(rng, cfg.chunk_len, cfg.d_model)
    attn_o = rand(rng, cfg.n_heads, cfg.chunk_len, cfg.head_dim)
    layers, _ = rand_params(cfg)
    p = layers[0]
    args = (x, attn_o, p["wo"], p["ln2_g"], p["w1"], p["w3"], p["w2"])
    dy = rand(rng, cfg.chunk_len, cfg.d_model)

    def scalar(*a):
        return jnp.sum(M.part2_fwd(cfg, *a) * dy)

    want = jax.grad(scalar, argnums=tuple(range(7)))(*args)
    got = M.part2_bwd(cfg, *args, dy)
    for g, w in zip(got, want):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=RTOL, atol=ATOL)


def test_head_loss_bwd_matches_autodiff(cfg):
    rng = np.random.default_rng(2)
    x = rand(rng, cfg.chunk_len, cfg.d_model)
    _, glob = rand_params(cfg)
    targets = jnp.asarray(rng.integers(0, cfg.vocab, cfg.chunk_len), jnp.int32)
    inv = jnp.float32(1.0 / cfg.seq_len)
    loss, dx, dg, dw = M.head_loss_bwd(cfg, x, glob["ln_f_g"], glob["w_head"], targets, inv)
    want_loss = M.head_loss_fwd(cfg, x, glob["ln_f_g"], glob["w_head"], targets, inv)
    assert_allclose(float(loss), float(want_loss), rtol=1e-6)
    want = jax.grad(
        lambda x, g, w: M.head_loss_fwd(cfg, x, g, w, targets, inv), argnums=(0, 1, 2)
    )(x, glob["ln_f_g"], glob["w_head"])
    for g, w in zip((dx, dg, dw), want):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=RTOL, atol=ATOL)


def test_embed_bwd_is_scatter_add(cfg):
    rng = np.random.default_rng(3)
    ids = jnp.asarray(rng.integers(0, cfg.vocab, cfg.chunk_len), jnp.int32)
    dx = rand(rng, cfg.chunk_len, cfg.d_model)
    _, glob = rand_params(cfg)
    want = jax.grad(lambda w: jnp.sum(M.embed_fwd(cfg, ids, w) * dx))(glob["w_emb"])
    got = M.embed_bwd(cfg, ids, dx)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=ATOL)


def test_split_layer_composes_to_full_layer(cfg):
    """part1 + chunked pallas attention + rescale-free ring + part2, run
    chunk-by-chunk, equals the monolithic full-sequence layer."""
    rng = np.random.default_rng(4)
    n, c, p = cfg.seq_len, cfg.chunk_len, cfg.n_workers
    x_full = rand(rng, n, cfg.d_model)
    layers, _ = rand_params(cfg)
    prm = layers[0]
    want = M._layer_full(cfg, x_full, prm)

    # per-chunk part1
    qs, ks, vs = [], [], []
    for w in range(p):
        q, k, v = M.part1_fwd(
            cfg, x_full[w * c : (w + 1) * c], prm["ln1_g"], prm["wq"], prm["wk"], prm["wv"]
        )
        qs.append(q), ks.append(k), vs.append(v)

    # Alg.1 ring over chunks, using the exported attn wrappers
    outs = []
    for wp in range(p):
        h = cfg.n_heads
        o = jnp.zeros((h, c, cfg.head_dim), jnp.float32)
        m = jnp.full((h, c), -jnp.inf, jnp.float32)
        l = jnp.zeros((h, c), jnp.float32)
        o, m, l = M.attn_fwd(cfg, qs[wp], ks[wp], vs[wp], o, m, l, causal=True)
        for r in range(wp):
            o, m, l = M.attn_fwd(cfg, qs[wp], ks[r], vs[r], o, m, l, causal=False)
        onorm, _ = M.attn_finalize(o, m, l)
        y = M.part2_fwd(
            cfg, x_full[wp * c : (wp + 1) * c], onorm,
            prm["wo"], prm["ln2_g"], prm["w1"], prm["w3"], prm["w2"],
        )
        outs.append(y)
    got = jnp.concatenate(outs, axis=0)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-4)


def test_attn_bwd_gqa_grouping(cfg):
    """attn_bwd must return kv grads grouped back to KVH heads and match
    autodiff of the replicated-head oracle."""
    rng = np.random.default_rng(5)
    h, kvh, c, d = cfg.n_heads, cfg.n_kv_heads, cfg.chunk_len, cfg.head_dim
    q = rand(rng, h, c, d)
    k = rand(rng, kvh, c, d)
    v = rand(rng, kvh, c, d)
    do = rand(rng, h, c, d)

    def f(q, k, v):
        kf = M.repeat_kv(k, cfg.group_size)
        vf = M.repeat_kv(v, cfg.group_size)
        return jnp.sum(kref.mha_full_attention_ref(q, kf, vf, causal=True) * do)

    want = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    kf = M.repeat_kv(k, cfg.group_size)
    vf = M.repeat_kv(v, cfg.group_size)

    def one(qh, kh, vh):
        return kref.full_attention_lse_ref(qh, kh, vh, causal=True)

    o, lse = jax.vmap(one)(q, kf, vf)
    got = M.attn_bwd(cfg, q, k, v, o, lse, do, causal=True)
    for g, w in zip(got, want):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-3, atol=1e-4)


def test_full_model_loss_decreases_under_sgd(cfg):
    """Sanity: a couple of full-batch SGD steps reduce the oracle loss."""
    rng = np.random.default_rng(6)
    ids = jnp.asarray(rng.integers(0, cfg.vocab, cfg.seq_len), jnp.int32)
    targets = jnp.asarray(rng.integers(0, cfg.vocab, cfg.seq_len), jnp.int32)
    layers, glob = rand_params(cfg)
    flat = M.flatten_params(layers, glob)

    loss_fn = jax.jit(lambda *f: M.full_model_loss_flat(cfg, ids, targets, *f))
    grad_fn = jax.jit(jax.value_and_grad(lambda fl: M.full_model_loss_flat(cfg, ids, targets, *fl)))
    l0, g = grad_fn(flat)
    flat = [p - 0.5 * gi for p, gi in zip(flat, g)]
    l1, g = grad_fn(flat)
    flat = [p - 0.5 * gi for p, gi in zip(flat, g)]
    l2 = loss_fn(*flat)
    assert float(l1) < float(l0)
    assert float(l2) < float(l1)


def test_full_model_grads_flat_matches_value_and_grad(cfg):
    rng = np.random.default_rng(7)
    ids = jnp.asarray(rng.integers(0, cfg.vocab, cfg.seq_len), jnp.int32)
    targets = jnp.asarray(rng.integers(0, cfg.vocab, cfg.seq_len), jnp.int32)
    layers, glob = rand_params(cfg)
    flat = M.flatten_params(layers, glob)
    out = M.full_model_grads_flat(cfg, ids, targets, *flat)
    loss, grads = out[0], out[1:]
    wl, wg = jax.value_and_grad(lambda fl: M.full_model_loss_flat(cfg, ids, targets, *fl))(flat)
    assert_allclose(float(loss), float(wl), rtol=1e-6)
    assert len(grads) == len(wg)
    for g, w in zip(grads, wg):
        assert_allclose(np.asarray(g), np.asarray(w), rtol=RTOL, atol=ATOL)
